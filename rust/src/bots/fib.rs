//! `fib` — recursive Fibonacci (BOTS `fib.c`).
//!
//! The pure runtime-overhead probe: no data, exponentially many tiny tasks.
//! Below `cutoff` the recursion is executed serially inside the task (the
//! BOTS manual-cutoff idiom), costing one compute unit bundle proportional
//! to the subtree's node count.

use crate::config::Size;
use crate::coordinator::task::{BodyCtx, TaskDesc, Workload};
use crate::simnuma::{MemSim, Region};
use crate::util::Time;

/// Compute units charged per visited fib node (call+add).
const UNITS_PER_NODE: u64 = 4;

pub struct Fib {
    n: u32,
    cutoff: u32,
    /// Shared config page (n, cutoff): the affinity region every spawn is
    /// hinted with, like the other annotated BOTS workloads.
    config: Region,
}

impl Fib {
    pub fn new(size: Size) -> Self {
        // cutoffs keep leaf work comfortably above the per-task runtime
        // overhead (the BOTS manual-cutoff tuning guidance)
        let (n, cutoff) = match size {
            Size::Small => (22, 12),
            Size::Medium => (28, 14),
            Size::Large => (32, 16),
            // 1,028,457 tasks (task_count(40, 14)) — the million-task
            // runtime-overhead probe behind the perf-xl bench cells
            Size::XL => (40, 14),
        };
        Self { n, cutoff, config: Region::EMPTY }
    }

    pub fn with_params(n: u32, cutoff: u32) -> Self {
        Self { n, cutoff, config: Region::EMPTY }
    }
}

/// Nodes in the call tree of fib(n): 2*fib(n+1) - 1.
pub fn call_tree_nodes(n: u32) -> u64 {
    2 * fib_value(n + 1) - 1
}

/// fib(0)=0, fib(1)=1.
pub fn fib_value(n: u32) -> u64 {
    let (mut a, mut b) = (0u64, 1u64);
    for _ in 0..n {
        let c = a + b;
        a = b;
        b = c;
    }
    a
}

/// Task count of the truncated tree (tasks spawned above the cutoff).
pub fn task_count(n: u32, cutoff: u32) -> u64 {
    if n < cutoff {
        1
    } else {
        1 + task_count(n - 1, cutoff) + task_count(n.saturating_sub(2), cutoff)
    }
}

impl Workload for Fib {
    fn name(&self) -> &'static str {
        "fib"
    }

    fn init(&mut self, mem: &mut MemSim, master_core: usize) -> Time {
        // a single shared config page (n, cutoff).  Deliberately tiny:
        // below every placement scheduler's default min-hint floor, so
        // the hints exist without changing default-parameter behaviour.
        // No ctx.read in the body — fib stays the pure overhead probe
        // (work conservation is pinned by an exact-equality test).
        self.config = mem.alloc(256);
        mem.first_touch(master_core, self.config, 0)
    }

    fn root(&self) -> TaskDesc {
        TaskDesc::new(0, [self.n as i64, 0, 0, 0])
    }

    fn body(&self, desc: TaskDesc, ctx: &mut BodyCtx) {
        let n = desc.args[0] as u32;
        if n < self.cutoff {
            // serial subtree
            ctx.compute(call_tree_nodes(n) * UNITS_PER_NODE);
            return;
        }
        ctx.spawn_on(TaskDesc::new(0, [n as i64 - 1, 0, 0, 0]), self.config);
        ctx.spawn_on(TaskDesc::new(0, [n as i64 - 2, 0, 0, 0]), self.config);
        ctx.taskwait();
        ctx.compute(UNITS_PER_NODE); // the add
    }

    fn task_count_hint(&self) -> Option<u64> {
        Some(task_count(self.n, self.cutoff))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::binding::BindPolicy;
    use crate::coordinator::runtime::Runtime;
    use crate::coordinator::sched::Policy;

    #[test]
    fn fib_values() {
        assert_eq!(fib_value(0), 0);
        assert_eq!(fib_value(10), 55);
        assert_eq!(call_tree_nodes(2), 3); // fib(2) calls fib(1), fib(0)
    }

    #[test]
    fn task_count_matches_run() {
        let rt = Runtime::paper_testbed();
        let mut w = Fib::with_params(12, 6);
        let stats = rt.run(&mut w, Policy::WorkFirst, BindPolicy::Linear, 4, 1, None).unwrap();
        assert_eq!(stats.tasks, task_count(12, 6));
    }

    #[test]
    fn total_work_is_policy_invariant() {
        // Work conservation: compute charged is identical across policies.
        let rt = Runtime::paper_testbed();
        let mut works = Vec::new();
        for &p in &[Policy::Serial, Policy::BreadthFirst, Policy::WorkFirst, Policy::Dfwsrpt] {
            let threads = if p == Policy::Serial { 1 } else { 8 };
            let mut w = Fib::with_params(14, 7);
            let s = rt.run(&mut w, p, BindPolicy::Linear, threads, 3, None).unwrap();
            works.push(s.work_time);
        }
        for w in &works[1..] {
            assert_eq!(*w, works[0]);
        }
    }

    #[test]
    fn scales_with_threads() {
        let rt = Runtime::paper_testbed();
        let mut w1 = Fib::new(Size::Small);
        let serial = rt.run_serial(&mut w1, 1).unwrap();
        let mut w8 = Fib::new(Size::Small);
        let par = rt.run(&mut w8, Policy::WorkFirst, BindPolicy::Linear, 8, 1, None).unwrap();
        let sp = serial.makespan as f64 / par.makespan as f64;
        assert!(sp > 2.0, "fib speedup {sp} too low");
    }
}
