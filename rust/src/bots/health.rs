//! `health` — Columbian health-care simulation (BOTS `health.c`).
//!
//! A fixed multilevel village hierarchy simulated over discrete time
//! steps; every step walks the tree with one task per village, each
//! touching that village's patient lists.  Moderate data, repeated
//! traversal — locality across steps matters (a village processed on the
//! same core re-hits its caches; after first-touch its pages stay on the
//! first toucher's node).
//!
//! Steps are chained through the post phase: `Step(t)` spawns the village
//! recursion, waits, then spawns `Step(t+1)`.

use crate::bots::mix;
use crate::config::Size;
use crate::coordinator::task::{BodyCtx, TaskDesc, Workload};
use crate::simnuma::{MemSim, Region};
use crate::util::Time;

const K_STEP: u16 = 0;
const K_VILLAGE: u16 = 1;

pub struct Health {
    branching: u32,
    depth: u32,
    steps: u32,
    villages: Vec<Region>,
}

impl Health {
    pub fn new(size: Size) -> Self {
        let (branching, depth, steps) = match size {
            Size::Small => (4, 3, 10),
            Size::Medium => (4, 5, 40),
            Size::Large | Size::XL => (4, 5, 100),
        };
        Self::with_params(branching, depth, steps)
    }

    pub fn with_params(branching: u32, depth: u32, steps: u32) -> Self {
        Self { branching, depth, steps, villages: Vec::new() }
    }

    pub fn village_count(&self) -> usize {
        // full b-ary tree with `depth` levels
        let b = self.branching as usize;
        (0..self.depth).map(|d| b.pow(d)).sum()
    }

    fn depth_of(&self, v: usize) -> u32 {
        let b = self.branching as usize;
        let mut lo = 0;
        let mut layer = 1;
        let mut d = 0;
        loop {
            if v < lo + layer {
                return d;
            }
            lo += layer;
            layer *= b;
            d += 1;
        }
    }
}

impl Workload for Health {
    fn name(&self) -> &'static str {
        "health"
    }

    fn init(&mut self, mem: &mut MemSim, master_core: usize) -> Time {
        let count = self.village_count();
        self.villages = (0..count)
            .map(|v| {
                // deeper villages are smaller clinics
                let bytes = 16 * 1024 >> self.depth_of(v).min(3);
                mem.alloc(bytes as u64)
            })
            .collect();
        let mut t = 0;
        for v in 0..count {
            t += mem.first_touch(master_core, self.villages[v], t);
        }
        t
    }

    fn root(&self) -> TaskDesc {
        TaskDesc::new(K_STEP, [0, 0, 0, 0])
    }

    fn body(&self, desc: TaskDesc, ctx: &mut BodyCtx) {
        match desc.kind {
            K_STEP => {
                let t = desc.args[0] as u32;
                // affinity: the root village task updates village 0's lists
                ctx.spawn_on(TaskDesc::new(K_VILLAGE, [0, t as i64, 0, 0]), self.villages[0]);
                ctx.taskwait();
                if t + 1 < self.steps {
                    ctx.spawn(TaskDesc::new(K_STEP, [(t + 1) as i64, 0, 0, 0]));
                }
            }
            K_VILLAGE => {
                let v = desc.args[0] as usize;
                let t = desc.args[1] as u64;
                let d = self.depth_of(v);
                // spawn child villages first (depth-first wavefront)
                if d + 1 < self.depth {
                    let b = self.branching as usize;
                    for c in 0..b {
                        let child = v * b + c + 1;
                        // each child task walks its own village's lists
                        ctx.spawn_on(
                            TaskDesc::new(K_VILLAGE, [child as i64, t as i64, 0, 0]),
                            self.villages[child],
                        );
                    }
                }
                // simulate this village: patients arrive/heal/refer
                let region = self.villages[v];
                ctx.read(region);
                ctx.compute(800 + mix(v as u64, t) % 800);
                ctx.write(region);
                if d + 1 < self.depth {
                    ctx.taskwait();
                    ctx.compute(200); // merge referrals from children
                }
            }
            other => panic!("health: unknown task kind {other}"),
        }
    }

    fn task_count_hint(&self) -> Option<u64> {
        Some(self.steps as u64 * (self.village_count() as u64 + 1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::binding::BindPolicy;
    use crate::coordinator::runtime::Runtime;
    use crate::coordinator::sched::Policy;

    #[test]
    fn village_tree_size() {
        let h = Health::with_params(4, 3, 1);
        assert_eq!(h.village_count(), 1 + 4 + 16);
        assert_eq!(h.depth_of(0), 0);
        assert_eq!(h.depth_of(1), 1);
        assert_eq!(h.depth_of(5), 2);
    }

    #[test]
    fn task_count_is_steps_times_villages() {
        let rt = Runtime::paper_testbed();
        let mut w = Health::with_params(3, 3, 5);
        let hint = w.task_count_hint().unwrap();
        let s = rt.run(&mut w, Policy::WorkFirst, BindPolicy::Linear, 4, 1, None).unwrap();
        assert_eq!(s.tasks, hint);
    }

    #[test]
    fn repeated_steps_hit_caches() {
        let rt = Runtime::paper_testbed();
        let mut w = Health::with_params(3, 3, 10);
        let s = rt.run_serial(&mut w, 1).unwrap();
        // after step 1 the villages are cache-resident for a 1-thread run
        let hits = s.mem.l1_hit_lines + s.mem.l2_hit_lines;
        assert!(hits > s.mem.miss_lines(), "locality should dominate");
    }

    #[test]
    fn completes_under_every_policy() {
        let rt = Runtime::paper_testbed();
        for &p in Policy::all() {
            let threads = if p == Policy::Serial { 1 } else { 8 };
            let mut w = Health::with_params(4, 3, 3);
            rt.run(&mut w, p, BindPolicy::Linear, threads, 2, None).unwrap();
        }
    }
}
