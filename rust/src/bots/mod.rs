//! The Barcelona OpenMP Tasks Suite (BOTS v1.1.2) — the paper's workload
//! set, rebuilt as deterministic task-graph generators over the
//! [`Workload`](crate::coordinator::task::Workload) trait.
//!
//! Eleven benchmarks, as in the paper (§V: "the eleven benchmarks" —
//! SparseLU counts twice via its `single` and `for` task-generation
//! variants):
//!
//! | module | data | tasks | paper figure |
//! |---|---|---|---|
//! | [`fib`]        | none   | many tiny    | — (overhead probe) |
//! | [`floorplan`]  | small  | irregular B&B| Fig 5 |
//! | [`sparselu`]   | blocks | phased       | Fig 6 (for), §V (single) |
//! | [`fft`]        | huge   | millions*    | Figs 7, 13 |
//! | [`strassen`]   | huge   | 7-ary tree   | Figs 8, 15 |
//! | [`sort`]       | huge   | merge tree   | Figs 9, 14 |
//! | [`nqueens`]    | none   | search tree  | Fig 10 |
//! | [`health`]     | medium | stepped tree | §V |
//! | [`alignment`]  | medium | independent  | §V |
//! | [`uts`]        | none   | unbalanced   | §V |
//!
//! *scaled ~100–1000x down so a figure regenerates in seconds while
//! preserving the footprint-to-node-capacity and task-granularity ratios
//! the paper's effects depend on (DESIGN.md §2).
//!
//! Each module documents its BOTS original, its task decomposition and the
//! scaling; compute leaves carry `Action::Kernel` tags so PJRT mode can
//! run the real Pallas/JAX artifacts (e.g. `matmul_f32_128` for Strassen
//! leaves).

pub mod alignment;
pub mod fft;
pub mod fib;
pub mod floorplan;
pub mod health;
pub mod nqueens;
pub mod sort;
pub mod sparselu;
pub mod strassen;
pub mod uts;

use anyhow::{bail, Result};

use crate::config::Size;
use crate::coordinator::task::Workload;

/// The eleven paper benchmarks.
pub const NAMES: &[&str] = &[
    "fib",
    "floorplan",
    "fft",
    "sort",
    "strassen",
    "sparselu_single",
    "sparselu_for",
    "nqueens",
    "health",
    "alignment",
    "uts",
];

/// Instantiate a benchmark by name.
pub fn create(name: &str, size: Size, seed: u64) -> Result<Box<dyn Workload>> {
    Ok(match name {
        "fib" => Box::new(fib::Fib::new(size)),
        "floorplan" => Box::new(floorplan::Floorplan::new(size, seed)),
        "fft" => Box::new(fft::Fft::new(size)),
        "sort" => Box::new(sort::Sort::new(size)),
        "strassen" => Box::new(strassen::Strassen::new(size)),
        "sparselu_single" => Box::new(sparselu::SparseLu::new(size, sparselu::Variant::Single)),
        "sparselu_for" => Box::new(sparselu::SparseLu::new(size, sparselu::Variant::For)),
        "nqueens" => Box::new(nqueens::NQueens::new(size)),
        "health" => Box::new(health::Health::new(size)),
        "alignment" => Box::new(alignment::Alignment::new(size)),
        "uts" => Box::new(uts::Uts::new(size, seed)),
        other => bail!("unknown benchmark '{other}' (see `numanos list`)"),
    })
}

/// Stateless mixing hash for deterministic workload shapes (UTS node
/// branching, floorplan pruning) — SplitMix64 finalizer.
#[inline]
pub(crate) fn mix(a: u64, b: u64) -> u64 {
    let mut z = a ^ b.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_eleven() {
        assert_eq!(NAMES.len(), 11);
        for name in NAMES {
            let w = create(name, Size::Small, 1).unwrap();
            assert!(!w.name().is_empty());
        }
        assert!(create("bogus", Size::Small, 1).is_err());
    }

    #[test]
    fn mix_is_deterministic_and_spreads() {
        assert_eq!(mix(1, 2), mix(1, 2));
        assert_ne!(mix(1, 2), mix(2, 1));
        assert_ne!(mix(0, 0), mix(0, 1));
    }
}
