//! `strassen` — Strassen-Winograd matrix multiply (BOTS `strassen.c`).
//!
//! High memory use (paper: ~7 GB) and a 7-ary recursion tree with chunky
//! leaves — the workload where DFWSRPT shines (Fig 15: many steals, so
//! randomized victim selection de-convoys the lowest-id neighbour).
//!
//! Decomposition: `Mul(node, size)` over views `(A_v, B_v, C_v)`.
//! Internal nodes pre-compute the quadrant sums (reads of both operand
//! views), spawn the seven sub-products into **per-node temp regions**,
//! and recombine in the post phase.  The temp regions are only
//! *address-space* at init; their pages are **first-touched by whichever
//! worker executes the writing task** — so a remote thief pulls the
//! product's pages to its own node, exactly the dynamic the paper's
//! NUMA-aware stealing exploits.
//!
//! PJRT mode: the first leaf triggers a real one-level Strassen of a
//! 256x256 product — seven `matmul_f32_128` calls plus the
//! `strassen_combine_f32_128` artifact — verified against a naive matmul.

use crate::config::Size;
use crate::coordinator::task::{BodyCtx, TaskDesc, Workload};
use crate::runtime::{Buf, ExecEngine};
use crate::simnuma::{MemSim, Region};
use crate::util::Time;

const K_MUL: u16 = 0;

pub const STRASSEN_LEAF_KERNEL: u64 = 3;

const ELEM: u64 = 4; // f32

pub struct Strassen {
    n: u64,
    a: Region,
    b: Region,
    c: Region,
    /// temp product regions `m[7]` per internal node, indexed by node id
    /// (7-ary heap numbering: children of `id` are `7*id+1 ..= 7*id+7`).
    temps: Vec<[Region; 7]>,
    levels: u32,
    real_done: bool,
    real_c: Option<Vec<f32>>,
    real_a: Vec<f32>,
    real_b: Vec<f32>,
}

impl Strassen {
    pub fn new(size: Size) -> Self {
        let (n, leaf) = match size {
            Size::Small => (512, 128),
            Size::Medium => (1024, 128),
            Size::Large | Size::XL => (1024, 64),
        };
        Self::with_params(n, leaf)
    }

    pub fn with_params(n: u64, leaf: u64) -> Self {
        assert!(n.is_power_of_two() && leaf.is_power_of_two() && leaf <= n);
        let levels = (n / leaf).trailing_zeros();
        Self {
            n,
            a: Region::EMPTY,
            b: Region::EMPTY,
            c: Region::EMPTY,
            temps: Vec::new(),
            levels,
            real_done: false,
            real_c: None,
            real_a: Vec::new(),
            real_b: Vec::new(),
        }
    }

    fn internal_nodes(&self) -> usize {
        // 1 + 7 + … + 7^(levels-1)
        let mut total = 0usize;
        let mut layer = 1usize;
        for _ in 0..self.levels {
            total += layer;
            layer *= 7;
        }
        total
    }

    /// Size of the product a node computes (root = n).
    fn node_size(&self, depth: u32) -> u64 {
        self.n >> depth
    }

    /// Operand/result views of a node: the root owns (A,B,C); any other
    /// node's views live in its parent's temp block `k`.
    fn views(&self, node: usize) -> (Region, Region, Region) {
        if node == 0 {
            return (self.a, self.b, self.c);
        }
        let parent = (node - 1) / 7;
        let k = (node - 1) % 7;
        let m = self.temps[parent][k];
        // operands of a sub-product are quadrant sums of the parent's
        // operands; we model their traffic in the parent's pre phase and
        // give the child its result region to write plus proportional
        // operand slices of the parent's views (see body()).
        let (pa, pb, _) = self.views(parent);
        let quarter_a = Region { addr: pa.addr, bytes: pa.bytes / 4 };
        let quarter_b = Region { addr: pb.addr + (k as u64 % 4) * pb.bytes / 4, bytes: pb.bytes / 4 };
        (quarter_a, quarter_b, m)
    }
}

impl Workload for Strassen {
    fn name(&self) -> &'static str {
        "strassen"
    }

    fn init(&mut self, mem: &mut MemSim, master_core: usize) -> Time {
        let bytes = self.n * self.n * ELEM;
        self.a = mem.alloc(bytes);
        self.b = mem.alloc(bytes);
        self.c = mem.alloc(bytes);
        // temp product blocks for every internal node (address space only —
        // placement happens on first write by the executing worker)
        let internal = self.internal_nodes();
        self.temps = (0..internal)
            .map(|node| {
                let depth = depth_of(node);
                let s = self.node_size(depth) / 2;
                std::array::from_fn(|_| mem.alloc(s * s * ELEM))
            })
            .collect();
        // master initializes the operands (first-touch on its node)
        let mut t = mem.first_touch(master_core, self.a, 0);
        t += mem.first_touch(master_core, self.b, t);

        // real 256x256 operands for PJRT verification
        self.real_a = (0..256 * 256).map(|i| ((i * 31 + 7) % 23) as f32 / 23.0 - 0.5).collect();
        self.real_b = (0..256 * 256).map(|i| ((i * 17 + 3) % 19) as f32 / 19.0 - 0.5).collect();
        t
    }

    fn root(&self) -> TaskDesc {
        TaskDesc::new(K_MUL, [0, 0, 0, 0])
    }

    fn body(&self, desc: TaskDesc, ctx: &mut BodyCtx) {
        debug_assert_eq!(desc.kind, K_MUL);
        let node = desc.args[0] as usize;
        let depth = desc.args[1] as u32;
        let s = self.node_size(depth);
        let (av, bv, cv) = self.views(node);

        if depth == self.levels {
            // leaf product: C_v = A_v x B_v on the MXU tile
            ctx.read(av);
            ctx.read(bv);
            ctx.kernel(STRASSEN_LEAF_KERNEL);
            // 2*s^3 flops at ~4 flops per unit-ns (SSE2-era dgemm-ish)
            ctx.compute(2 * s * s * s / 4);
            ctx.write(cv);
            return;
        }

        // pre: quadrant sums S1..S7 — stream both operands, write temps'
        // first halves (operand scratch modeled inside the temp block)
        ctx.read(av);
        ctx.read(bv);
        ctx.compute(10 * (s / 2) * (s / 2) / 4); // Winograd pre-adds
        for k in 0..7usize {
            let child = 7 * node + 1 + k;
            // affinity: the sub-product streams its operand quadrant (its
            // temp result is first-touched wherever the child executes)
            let (child_a, _, _) = self.views(child);
            ctx.spawn_on(
                TaskDesc::new(K_MUL, [child as i64, depth as i64 + 1, 0, 0]),
                child_a,
            );
        }
        ctx.taskwait();
        // post: recombine the seven products into C_v
        for m in &self.temps[node] {
            ctx.read(*m);
        }
        ctx.compute(8 * (s / 2) * (s / 2) / 4); // Winograd post-adds
        ctx.write(cv);
    }

    fn run_kernel(&mut self, tag: u64, exec: &mut ExecEngine) -> anyhow::Result<()> {
        if tag != STRASSEN_LEAF_KERNEL || self.real_done {
            return Ok(());
        }
        self.real_done = true;
        let n = 256usize;
        let h = n / 2;
        let quad = |m: &[f32], qi: usize, qj: usize| -> Vec<f32> {
            let mut q = vec![0f32; h * h];
            for r in 0..h {
                for c in 0..h {
                    q[r * h + c] = m[(qi * h + r) * n + (qj * h + c)];
                }
            }
            q
        };
        let add = |x: &[f32], y: &[f32]| -> Vec<f32> {
            x.iter().zip(y).map(|(a, b)| a + b).collect()
        };
        let sub = |x: &[f32], y: &[f32]| -> Vec<f32> {
            x.iter().zip(y).map(|(a, b)| a - b).collect()
        };
        let (a11, a12, a21, a22) = (
            quad(&self.real_a, 0, 0),
            quad(&self.real_a, 0, 1),
            quad(&self.real_a, 1, 0),
            quad(&self.real_a, 1, 1),
        );
        let (b11, b12, b21, b22) = (
            quad(&self.real_b, 0, 0),
            quad(&self.real_b, 0, 1),
            quad(&self.real_b, 1, 0),
            quad(&self.real_b, 1, 1),
        );
        let shape = [h as i64, h as i64];
        let mut mm = |x: Vec<f32>, y: Vec<f32>| -> anyhow::Result<Vec<f32>> {
            exec.call1("matmul_f32_128", &[Buf::f32(x, &shape), Buf::f32(y, &shape)])
        };
        // classic Strassen products matching python model.strassen_combine
        let m1 = mm(add(&a11, &a22), add(&b11, &b22))?;
        let m2 = mm(add(&a21, &a22), b11.clone())?;
        let m3 = mm(a11.clone(), sub(&b12, &b22))?;
        let m4 = mm(a22.clone(), sub(&b21, &b11))?;
        let m5 = mm(add(&a11, &a12), b22.clone())?;
        let m6 = mm(sub(&a21, &a11), add(&b11, &b12))?;
        let m7 = mm(sub(&a12, &a22), add(&b21, &b22))?;
        let bufs: Vec<Buf> = [m1, m2, m3, m4, m5, m6, m7]
            .into_iter()
            .map(|m| Buf::f32(m, &shape))
            .collect();
        self.real_c = Some(exec.call1("strassen_combine_f32_128", &bufs)?);
        Ok(())
    }

    fn verify(&self, _exec: &mut ExecEngine) -> anyhow::Result<()> {
        let Some(got) = &self.real_c else {
            anyhow::bail!("strassen: no kernel output captured");
        };
        let n = 256usize;
        let mut max_err = 0f64;
        // sampled naive check (full 256^3 is fine, keep it simple & exact)
        for r in 0..n {
            for c in 0..n {
                let mut acc = 0f64;
                for k in 0..n {
                    acc += self.real_a[r * n + k] as f64 * self.real_b[k * n + c] as f64;
                }
                max_err = max_err.max((got[r * n + c] as f64 - acc).abs());
            }
        }
        anyhow::ensure!(max_err < 2e-3, "strassen mismatch: max err {max_err}");
        Ok(())
    }

    fn task_count_hint(&self) -> Option<u64> {
        Some((0..=self.levels).map(|d| 7u64.pow(d)).sum())
    }
}

fn depth_of(node: usize) -> u32 {
    // 7-ary heap depth
    let mut d = 0;
    let mut lo = 0usize;
    let mut count = 1usize;
    loop {
        if node < lo + count {
            return d;
        }
        lo += count;
        count *= 7;
        d += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::binding::BindPolicy;
    use crate::coordinator::runtime::Runtime;
    use crate::coordinator::sched::Policy;

    #[test]
    fn depth_numbering() {
        assert_eq!(depth_of(0), 0);
        for k in 1..=7 {
            assert_eq!(depth_of(k), 1);
        }
        assert_eq!(depth_of(8), 2);
        assert_eq!(depth_of(7 + 49), 2);
        assert_eq!(depth_of(8 + 49), 3);
    }

    #[test]
    fn task_count_is_sevenary_tree() {
        let rt = Runtime::paper_testbed();
        let mut w = Strassen::with_params(512, 128); // 2 levels: 1+7+49
        let s = rt.run(&mut w, Policy::WorkFirst, BindPolicy::Linear, 8, 1, None).unwrap();
        assert_eq!(s.tasks, 57);
        assert_eq!(w.task_count_hint(), Some(57));
    }

    #[test]
    fn temps_are_worker_touched() {
        // temp pages must NOT all land on the master's node under stealing
        let rt = Runtime::paper_testbed();
        let mut w = Strassen::with_params(512, 64);
        let s = rt.run(&mut w, Policy::Dfwsrpt, BindPolicy::NumaAware, 16, 9, None).unwrap();
        assert!(s.steals > 0);
        assert!(s.mem.first_touch_pages > 0);
    }

    #[test]
    fn all_policies_same_task_count() {
        let rt = Runtime::paper_testbed();
        let mut counts = Vec::new();
        for &p in &[Policy::Serial, Policy::BreadthFirst, Policy::CilkBased, Policy::Dfwspt] {
            let threads = if p == Policy::Serial { 1 } else { 8 };
            let mut w = Strassen::with_params(512, 128);
            let s = rt.run(&mut w, p, BindPolicy::Linear, threads, 4, None).unwrap();
            counts.push(s.tasks);
        }
        assert!(counts.windows(2).all(|w| w[0] == w[1]));
    }
}
