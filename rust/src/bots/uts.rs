//! `uts` — Unbalanced Tree Search (BOTS `uts.c`, binomial variant).
//!
//! The load-balance torture test: a hash-derived tree whose subtree sizes
//! vary wildly, with essentially no data.  Stock work stealing handles it
//! well; the paper groups it with the non-data-intensive benchmarks (small
//! NUMA gains).
//!
//! Binomial model: the root has `b0` children; every other node has `m`
//! children with probability `q` (here qm ≈ 0.99 < 1, so the expected tree
//! is finite ≈ b0/(1-qm) nodes).  Branching decisions come from a
//! SplitMix-style hash of (seed, node id) — deterministic, seedable, and
//! a faithful stand-in for UTS's SHA-1 stream.  A depth cap bounds the
//! geometric tail (documented deviation; hit with probability < 1e-6).

use crate::bots::mix;
use crate::config::Size;
use crate::coordinator::task::{BodyCtx, TaskDesc, Workload};
use crate::simnuma::{MemSim, Region};
use crate::util::Time;

/// SHA-1-ish per-node compute charge.
const UNITS_PER_NODE: u64 = 220;
const DEPTH_CAP: u32 = 64;

pub struct Uts {
    b0: u32,
    m: u32,
    /// q in permille (q = q_pm / 1000)
    q_pm: u32,
    seed: u64,
    /// Shared tree-parameter page (b0, m, q, seed): the affinity region
    /// every spawn is hinted with, like the other annotated workloads.
    config: Region,
}

impl Uts {
    pub fn new(size: Size, seed: u64) -> Self {
        let b0 = match size {
            Size::Small => 64,
            Size::Medium => 500,
            Size::Large => 2000,
            // E[nodes] = 1 + b0/(1-qm) ≈ 1.13M at qm = 0.992 — the
            // million-task load-balance tree for the perf-xl cells
            Size::XL => 9000,
        };
        Self { b0, m: 8, q_pm: 124, seed, config: Region::EMPTY } // qm = 0.992
    }

    pub fn with_params(b0: u32, m: u32, q_pm: u32, seed: u64) -> Self {
        assert!(m as u64 * q_pm as u64 <= 1000, "qm must be < 1 for a finite tree");
        Self { b0, m, q_pm, seed, config: Region::EMPTY }
    }

    fn children(&self, node: u64, depth: u32) -> u32 {
        if depth >= DEPTH_CAP {
            return 0;
        }
        if node == 0 {
            return self.b0;
        }
        if mix(self.seed ^ node, depth as u64) % 1000 < self.q_pm as u64 {
            self.m
        } else {
            0
        }
    }
}

impl Workload for Uts {
    fn name(&self) -> &'static str {
        "uts"
    }

    fn init(&mut self, mem: &mut MemSim, master_core: usize) -> Time {
        // a single shared tree-parameter page.  Deliberately tiny: below
        // every placement scheduler's default min-hint floor, so the
        // hints exist without changing default-parameter behaviour (and
        // no ctx.read — uts stays essentially data-free).
        self.config = mem.alloc(256);
        mem.first_touch(master_core, self.config, 0)
    }

    fn root(&self) -> TaskDesc {
        TaskDesc::new(0, [0, 0, 0, 0])
    }

    fn body(&self, desc: TaskDesc, ctx: &mut BodyCtx) {
        let node = desc.args[0] as u64;
        let depth = desc.args[1] as u32;
        ctx.compute(UNITS_PER_NODE);
        let kids = self.children(node, depth);
        for c in 0..kids {
            // child ids: hash-derived, collision-free enough for shaping
            let child = mix(node.wrapping_add(1), c as u64 + 1) | 1;
            ctx.spawn_on(TaskDesc::new(0, [child as i64, depth as i64 + 1, 0, 0]), self.config);
        }
        if kids > 0 {
            ctx.taskwait();
            ctx.compute(20);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::binding::BindPolicy;
    use crate::coordinator::runtime::Runtime;
    use crate::coordinator::sched::Policy;

    #[test]
    fn tree_is_deterministic_per_seed() {
        let rt = Runtime::paper_testbed();
        let mut a = Uts::with_params(32, 8, 110, 5);
        let sa = rt.run(&mut a, Policy::WorkFirst, BindPolicy::Linear, 8, 1, None).unwrap();
        let mut b = Uts::with_params(32, 8, 110, 5);
        let sb = rt.run(&mut b, Policy::WorkFirst, BindPolicy::Linear, 8, 1, None).unwrap();
        assert_eq!(sa.tasks, sb.tasks);
        let mut c = Uts::with_params(32, 8, 110, 6);
        let sc = rt.run(&mut c, Policy::WorkFirst, BindPolicy::Linear, 8, 1, None).unwrap();
        assert_ne!(sa.tasks, sc.tasks, "different seed, different tree");
    }

    #[test]
    fn tree_is_unbalanced() {
        // distribution across workers should be very uneven without
        // stealing; with stealing every worker gets work
        let rt = Runtime::paper_testbed();
        let mut w = Uts::with_params(64, 8, 120, 3);
        let s = rt.run(&mut w, Policy::Dfwsrpt, BindPolicy::Linear, 8, 3, None).unwrap();
        assert!(s.steals > 0);
        assert!(s.per_worker_tasks.iter().all(|&t| t > 0), "{:?}", s.per_worker_tasks);
    }

    #[test]
    fn expected_size_ballpark() {
        // E[nodes] = 1 + b0/(1-qm); accept a wide band (hash variance)
        let rt = Runtime::paper_testbed();
        let mut w = Uts::with_params(128, 8, 110, 11); // qm=0.88
        let s = rt.run_serial(&mut w, 1).unwrap();
        let expect = 1.0 + 128.0 / (1.0 - 0.88);
        assert!(
            (s.tasks as f64) > expect * 0.2 && (s.tasks as f64) < expect * 5.0,
            "tasks {} vs E {}",
            s.tasks,
            expect
        );
    }

    #[test]
    fn qm_ge_one_rejected() {
        let r = std::panic::catch_unwind(|| Uts::with_params(10, 8, 130, 1));
        assert!(r.is_err());
    }
}
