//! Run statistics, speedup tables and the paper's reference numbers.

pub mod paper;
pub mod table;

use crate::coordinator::binding::BindPolicy;
use crate::simnuma::MemStats;
use crate::util::{fmt_time, Time};

/// Everything measured in one simulated run.
#[derive(Clone, Debug)]
pub struct RunStats {
    pub bench: String,
    /// Scheduler signature of this run — the registry name, plus
    /// resolved parameters for parameterized strategies
    /// (`hops-threshold(max_hops=1;spill_after=2)`).  The open successor
    /// of the old closed `Policy` enum field.
    pub sched: String,
    pub bind: Option<BindPolicy>,
    pub threads: usize,
    pub topo: String,
    pub seed: u64,
    /// Simulated completion time of the last task (the paper's metric).
    pub makespan: Time,
    /// Simulated cost of the untimed init phase (first-touch placement).
    pub init_time: Time,
    pub tasks: u64,
    pub peak_live: usize,
    pub steals: u64,
    pub steal_attempts: u64,
    pub mean_steal_hops: f64,
    /// Spawns a placement-aware scheduler pushed to a remote home-node
    /// pool instead of the local child-first switch (0 for stock
    /// schedulers).
    pub pushed_home: u64,
    /// Affinity-hinted spawns (at or above the scheduler's declared hint
    /// floor) whose data was already home on the spawner's node — the
    /// locality fast path (0 for stock schedulers).
    pub affinity_hits: u64,
    /// Successful steals whose stolen task was homed on the thief's node
    /// — what steal-bias aims to maximize (0 for stock schedulers, whose
    /// tasks carry no home tags).
    pub affine_steals: u64,
    /// Tied continuations a placing scheduler's resume hook released to
    /// a home-node worker instead of the first owner (0 for stock
    /// schedulers).
    pub homed_resumes: u64,
    /// Steals that transferred more than one task — the steal-half
    /// batching a `StealCand::take` above 1 requests (0 for stock
    /// schedulers and default-batch locality strategies).
    pub batch_steals: u64,
    /// Extra tasks moved by batched steals, beyond the one the thief ran
    /// (each was requeued on the thief's own pool under the same sweep).
    pub tasks_migrated: u64,
    /// Homed continuations picked up from a per-node mailbox by a
    /// same-node team member (0 for stock schedulers).
    pub mailbox_hits: u64,
    /// Total simulated time spent waiting on pool locks (contention).
    pub lock_wait_total: Time,
    pub shared_lock_wait: Time,
    pub shared_ops: u64,
    /// Aggregate worker time in compute+memory vs runtime overhead.
    pub work_time: Time,
    pub overhead_time: Time,
    pub per_worker_tasks: Vec<u64>,
    pub mem: MemStats,
    pub kernel_calls: u64,
    pub sim_events: u64,
    /// Host wall-clock of the simulation itself (engine perf tracking).
    pub wall_ms: f64,
}

impl RunStats {
    /// Config label like `wf-Scheduler-NUMA` (paper figure legend style).
    pub fn label(&self) -> String {
        if self.sched == "serial" {
            return "serial".into();
        }
        let sched = format!("{}-Scheduler", self.sched);
        match self.bind {
            Some(BindPolicy::NumaAware) => format!("{sched}-NUMA"),
            _ => sched,
        }
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{:<22} t={:<2} makespan={:<12} tasks={} steals={} (hops {:.2}) lockwait={} remote={:.1}%",
            self.label(),
            self.threads,
            fmt_time(self.makespan),
            self.tasks,
            self.steals,
            self.mean_steal_hops,
            fmt_time(self.lock_wait_total),
            100.0 * self.mem.remote_ratio(),
        )
    }

    /// Parallel efficiency diagnostic: work / (threads * makespan).
    pub fn efficiency(&self) -> f64 {
        if self.makespan == 0 {
            return 0.0;
        }
        self.work_time as f64 / (self.threads as f64 * self.makespan as f64)
    }
}

/// speedup = serial makespan / this makespan.
pub fn speedup(serial: &RunStats, run: &RunStats) -> f64 {
    serial.makespan as f64 / run.makespan as f64
}

/// Median of a wall-clock sample (sorts in place; even-length samples
/// average the middle pair).  The bench suite reports the median of
/// `--reps` repetitions so one scheduling hiccup on the host doesn't
/// read as an engine regression.  NaN for an empty sample.
pub fn median_ms(xs: &mut [f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.sort_by(|a, b| a.partial_cmp(b).expect("wall-clock samples are finite"));
    let mid = xs.len() / 2;
    if xs.len() % 2 == 1 {
        xs[mid]
    } else {
        (xs[mid - 1] + xs[mid]) / 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(sched: &str, bind: Option<BindPolicy>, makespan: Time) -> RunStats {
        RunStats {
            bench: "x".into(),
            sched: sched.to_string(),
            bind,
            threads: 4,
            topo: "x4600".into(),
            seed: 0,
            makespan,
            init_time: 0,
            tasks: 10,
            peak_live: 2,
            steals: 3,
            steal_attempts: 5,
            mean_steal_hops: 1.0,
            pushed_home: 0,
            affinity_hits: 0,
            affine_steals: 0,
            homed_resumes: 0,
            batch_steals: 0,
            tasks_migrated: 0,
            mailbox_hits: 0,
            lock_wait_total: 0,
            shared_lock_wait: 0,
            shared_ops: 0,
            work_time: makespan * 3,
            overhead_time: 0,
            per_worker_tasks: vec![3, 3, 2, 2],
            mem: MemStats::default(),
            kernel_calls: 0,
            sim_events: 0,
            wall_ms: 0.0,
        }
    }

    #[test]
    fn labels_match_paper_legends() {
        assert_eq!(
            stats("wf", Some(BindPolicy::NumaAware), 1).label(),
            "wf-Scheduler-NUMA"
        );
        assert_eq!(stats("bf", Some(BindPolicy::Linear), 1).label(), "bf-Scheduler");
        assert_eq!(stats("dfwsrpt", None, 1).label(), "dfwsrpt-Scheduler");
        assert_eq!(stats("serial", None, 1).label(), "serial");
        assert_eq!(
            stats("hops-threshold", Some(BindPolicy::NumaAware), 1).label(),
            "hops-threshold-Scheduler-NUMA"
        );
    }

    #[test]
    fn speedup_ratio() {
        let serial = stats("serial", None, 1000);
        let par = stats("wf", None, 250);
        assert!((speedup(&serial, &par) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn efficiency_bounded() {
        let s = stats("wf", None, 100);
        assert!(s.efficiency() > 0.0 && s.efficiency() <= 1.0);
    }

    #[test]
    fn median_of_samples() {
        assert!(median_ms(&mut []).is_nan());
        assert_eq!(median_ms(&mut [3.0]), 3.0);
        assert_eq!(median_ms(&mut [9.0, 1.0, 4.0]), 4.0);
        assert_eq!(median_ms(&mut [8.0, 2.0, 4.0, 6.0]), 5.0);
        // an outlier rep doesn't move the median
        assert_eq!(median_ms(&mut [10.0, 11.0, 500.0]), 11.0);
    }
}
