//! Run statistics, speedup tables and the paper's reference numbers.

pub mod paper;
pub mod table;

use anyhow::Context;

use crate::coordinator::binding::BindPolicy;
use crate::serde::Json;
use crate::simnuma::MemStats;
use crate::util::{fmt_time, Time};

/// Everything measured in one simulated run.
#[derive(Clone, Debug)]
pub struct RunStats {
    pub bench: String,
    /// Scheduler signature of this run — the registry name, plus
    /// resolved parameters for parameterized strategies
    /// (`hops-threshold(max_hops=1;spill_after=2)`).  The open successor
    /// of the old closed `Policy` enum field.
    pub sched: String,
    pub bind: Option<BindPolicy>,
    pub threads: usize,
    pub topo: String,
    pub seed: u64,
    /// Simulated completion time of the last task (the paper's metric).
    pub makespan: Time,
    /// Simulated cost of the untimed init phase (first-touch placement).
    pub init_time: Time,
    pub tasks: u64,
    pub peak_live: usize,
    pub steals: u64,
    pub steal_attempts: u64,
    pub mean_steal_hops: f64,
    /// Spawns a placement-aware scheduler pushed to a remote home-node
    /// pool instead of the local child-first switch (0 for stock
    /// schedulers).
    pub pushed_home: u64,
    /// Affinity-hinted spawns (at or above the scheduler's declared hint
    /// floor) whose data was already home on the spawner's node — the
    /// locality fast path (0 for stock schedulers).
    pub affinity_hits: u64,
    /// Successful steals whose stolen task was homed on the thief's node
    /// — what steal-bias aims to maximize (0 for stock schedulers, whose
    /// tasks carry no home tags).
    pub affine_steals: u64,
    /// Tied continuations a placing scheduler's resume hook released to
    /// a home-node worker instead of the first owner (0 for stock
    /// schedulers).
    pub homed_resumes: u64,
    /// Steals that transferred more than one task — the steal-half
    /// batching a `StealCand::take` above 1 requests (0 for stock
    /// schedulers and default-batch locality strategies).
    pub batch_steals: u64,
    /// Extra tasks moved by batched steals, beyond the one the thief ran
    /// (each was requeued on the thief's own pool under the same sweep).
    pub tasks_migrated: u64,
    /// Homed continuations picked up from a per-node mailbox by a
    /// same-node team member (0 for stock schedulers).
    pub mailbox_hits: u64,
    /// Total simulated time spent waiting on pool locks (contention).
    pub lock_wait_total: Time,
    pub shared_lock_wait: Time,
    pub shared_ops: u64,
    /// Aggregate worker time in compute+memory vs runtime overhead.
    pub work_time: Time,
    pub overhead_time: Time,
    pub per_worker_tasks: Vec<u64>,
    pub mem: MemStats,
    pub kernel_calls: u64,
    pub sim_events: u64,
    /// Host wall-clock of the simulation itself (engine perf tracking).
    pub wall_ms: f64,
}

impl RunStats {
    /// Config label like `wf-Scheduler-NUMA` (paper figure legend style).
    pub fn label(&self) -> String {
        if self.sched == "serial" {
            return "serial".into();
        }
        let sched = format!("{}-Scheduler", self.sched);
        match self.bind {
            Some(BindPolicy::NumaAware) => format!("{sched}-NUMA"),
            _ => sched,
        }
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{:<22} t={:<2} makespan={:<12} tasks={} steals={} (hops {:.2}) lockwait={} remote={:.1}%",
            self.label(),
            self.threads,
            fmt_time(self.makespan),
            self.tasks,
            self.steals,
            self.mean_steal_hops,
            fmt_time(self.lock_wait_total),
            100.0 * self.mem.remote_ratio(),
        )
    }

    /// Parallel efficiency diagnostic: work / (threads * makespan).
    pub fn efficiency(&self) -> f64 {
        if self.makespan == 0 {
            return 0.0;
        }
        self.work_time as f64 / (self.threads as f64 * self.makespan as f64)
    }

    /// Lossless JSON image of every field — the result store's record
    /// format.  Distinct from [`RunRecord::to_json`](crate::spec::RunRecord)
    /// (a curated report view): this one must round-trip exactly, so
    /// counters above 2^53 go through the lossless u64 encoding and
    /// `bind: None` survives as `null`.
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("bench", Json::from(self.bench.as_str())),
            ("sched", Json::from(self.sched.as_str())),
            (
                "bind",
                self.bind.map(|b| Json::from(b.name())).unwrap_or(Json::Null),
            ),
            ("threads", Json::from(self.threads)),
            ("topo", Json::from(self.topo.as_str())),
            ("seed", Json::from_u64_lossless(self.seed)),
            ("makespan", Json::from_u64_lossless(self.makespan)),
            ("init_time", Json::from_u64_lossless(self.init_time)),
            ("tasks", Json::from_u64_lossless(self.tasks)),
            ("peak_live", Json::from(self.peak_live)),
            ("steals", Json::from_u64_lossless(self.steals)),
            ("steal_attempts", Json::from_u64_lossless(self.steal_attempts)),
            ("mean_steal_hops", Json::from(self.mean_steal_hops)),
            ("pushed_home", Json::from_u64_lossless(self.pushed_home)),
            ("affinity_hits", Json::from_u64_lossless(self.affinity_hits)),
            ("affine_steals", Json::from_u64_lossless(self.affine_steals)),
            ("homed_resumes", Json::from_u64_lossless(self.homed_resumes)),
            ("batch_steals", Json::from_u64_lossless(self.batch_steals)),
            ("tasks_migrated", Json::from_u64_lossless(self.tasks_migrated)),
            ("mailbox_hits", Json::from_u64_lossless(self.mailbox_hits)),
            ("lock_wait_total", Json::from_u64_lossless(self.lock_wait_total)),
            ("shared_lock_wait", Json::from_u64_lossless(self.shared_lock_wait)),
            ("shared_ops", Json::from_u64_lossless(self.shared_ops)),
            ("work_time", Json::from_u64_lossless(self.work_time)),
            ("overhead_time", Json::from_u64_lossless(self.overhead_time)),
            (
                "per_worker_tasks",
                Json::Arr(self.per_worker_tasks.iter().map(|&t| Json::from_u64_lossless(t)).collect()),
            ),
            ("mem", self.mem.to_json()),
            ("kernel_calls", Json::from_u64_lossless(self.kernel_calls)),
            ("sim_events", Json::from_u64_lossless(self.sim_events)),
            ("wall_ms", Json::from(self.wall_ms)),
            // derived (never parsed back): engine throughput this run.
            // Regenerated from the two fields above, so round-tripping
            // through from_json → to_json stays byte-identical.
            ("events_per_sec", Json::from(self.events_per_sec())),
        ])
    }

    /// Simulated events retired per host second — the engine-throughput
    /// headline (0.0 before any wall time is recorded).
    pub fn events_per_sec(&self) -> f64 {
        if self.wall_ms > 0.0 {
            self.sim_events as f64 / (self.wall_ms / 1e3)
        } else {
            0.0
        }
    }

    /// Inverse of [`RunStats::to_json`]; strict — any missing or
    /// malformed field is an error (the store quarantines the record).
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let u = |k: &str| {
            j.get(k)
                .and_then(Json::as_u64_lossless)
                .with_context(|| format!("RunStats field '{k}'"))
        };
        let s = |k: &str| {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .with_context(|| format!("RunStats field '{k}'"))
        };
        let f = |k: &str| {
            j.get(k).and_then(Json::as_num).with_context(|| format!("RunStats field '{k}'"))
        };
        let bind = match j.get("bind") {
            None => anyhow::bail!("RunStats field 'bind'"),
            Some(Json::Null) => None,
            Some(v) => Some(BindPolicy::from_name(
                v.as_str().context("RunStats field 'bind'")?,
            )?),
        };
        let per_worker_tasks = j
            .get("per_worker_tasks")
            .and_then(Json::as_arr)
            .context("RunStats field 'per_worker_tasks'")?
            .iter()
            .map(|v| v.as_u64_lossless().context("RunStats 'per_worker_tasks' entry"))
            .collect::<anyhow::Result<Vec<u64>>>()?;
        Ok(Self {
            bench: s("bench")?,
            sched: s("sched")?,
            bind,
            threads: j
                .get("threads")
                .and_then(Json::as_usize)
                .context("RunStats field 'threads'")?,
            topo: s("topo")?,
            seed: u("seed")?,
            makespan: u("makespan")?,
            init_time: u("init_time")?,
            tasks: u("tasks")?,
            peak_live: j
                .get("peak_live")
                .and_then(Json::as_usize)
                .context("RunStats field 'peak_live'")?,
            steals: u("steals")?,
            steal_attempts: u("steal_attempts")?,
            mean_steal_hops: f("mean_steal_hops")?,
            pushed_home: u("pushed_home")?,
            affinity_hits: u("affinity_hits")?,
            affine_steals: u("affine_steals")?,
            homed_resumes: u("homed_resumes")?,
            batch_steals: u("batch_steals")?,
            tasks_migrated: u("tasks_migrated")?,
            mailbox_hits: u("mailbox_hits")?,
            lock_wait_total: u("lock_wait_total")?,
            shared_lock_wait: u("shared_lock_wait")?,
            shared_ops: u("shared_ops")?,
            work_time: u("work_time")?,
            overhead_time: u("overhead_time")?,
            per_worker_tasks,
            mem: MemStats::from_json(j.get("mem").context("RunStats field 'mem'")?)?,
            kernel_calls: u("kernel_calls")?,
            sim_events: u("sim_events")?,
            wall_ms: f("wall_ms")?,
        })
    }
}

/// speedup = serial makespan / this makespan.
pub fn speedup(serial: &RunStats, run: &RunStats) -> f64 {
    serial.makespan as f64 / run.makespan as f64
}

/// Median of a wall-clock sample (sorts in place; even-length samples
/// average the middle pair).  The bench suite reports the median of
/// `--reps` repetitions so one scheduling hiccup on the host doesn't
/// read as an engine regression.  NaN for an empty sample.
pub fn median_ms(xs: &mut [f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.sort_by(|a, b| a.partial_cmp(b).expect("wall-clock samples are finite"));
    let mid = xs.len() / 2;
    if xs.len() % 2 == 1 {
        xs[mid]
    } else {
        (xs[mid - 1] + xs[mid]) / 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(sched: &str, bind: Option<BindPolicy>, makespan: Time) -> RunStats {
        RunStats {
            bench: "x".into(),
            sched: sched.to_string(),
            bind,
            threads: 4,
            topo: "x4600".into(),
            seed: 0,
            makespan,
            init_time: 0,
            tasks: 10,
            peak_live: 2,
            steals: 3,
            steal_attempts: 5,
            mean_steal_hops: 1.0,
            pushed_home: 0,
            affinity_hits: 0,
            affine_steals: 0,
            homed_resumes: 0,
            batch_steals: 0,
            tasks_migrated: 0,
            mailbox_hits: 0,
            lock_wait_total: 0,
            shared_lock_wait: 0,
            shared_ops: 0,
            work_time: makespan * 3,
            overhead_time: 0,
            per_worker_tasks: vec![3, 3, 2, 2],
            mem: MemStats::default(),
            kernel_calls: 0,
            sim_events: 0,
            wall_ms: 0.0,
        }
    }

    #[test]
    fn labels_match_paper_legends() {
        assert_eq!(
            stats("wf", Some(BindPolicy::NumaAware), 1).label(),
            "wf-Scheduler-NUMA"
        );
        assert_eq!(stats("bf", Some(BindPolicy::Linear), 1).label(), "bf-Scheduler");
        assert_eq!(stats("dfwsrpt", None, 1).label(), "dfwsrpt-Scheduler");
        assert_eq!(stats("serial", None, 1).label(), "serial");
        assert_eq!(
            stats("hops-threshold", Some(BindPolicy::NumaAware), 1).label(),
            "hops-threshold-Scheduler-NUMA"
        );
    }

    #[test]
    fn speedup_ratio() {
        let serial = stats("serial", None, 1000);
        let par = stats("wf", None, 250);
        assert!((speedup(&serial, &par) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn efficiency_bounded() {
        let s = stats("wf", None, 100);
        assert!(s.efficiency() > 0.0 && s.efficiency() <= 1.0);
    }

    #[test]
    fn run_stats_json_round_trips() {
        let mut s = stats("numa-steal", Some(BindPolicy::NumaAware), 1234);
        // exercise the lossless path (> 2^53) and the mem sub-object
        s.sim_events = (1u64 << 60) + 7;
        s.mem.miss_lines_by_hop[3] = 42;
        s.mem.bytes_touched = 9999;
        for probe in [s.clone(), stats("serial", None, 8)] {
            let j = probe.to_json();
            let back = RunStats::from_json(&j).unwrap();
            assert_eq!(back.to_json().to_compact(), j.to_compact());
            assert_eq!(back.sim_events, probe.sim_events);
            assert_eq!(back.bind, probe.bind);
            assert_eq!(back.mem.miss_lines_by_hop, probe.mem.miss_lines_by_hop);
        }
    }

    #[test]
    fn run_stats_from_json_is_strict() {
        let full = stats("wf", None, 10).to_json();
        // dropping any required field must fail loudly
        for missing in ["makespan", "bind", "mem", "per_worker_tasks"] {
            let mut obj = full.as_obj().unwrap().clone();
            obj.remove(missing);
            assert!(
                RunStats::from_json(&Json::Obj(obj)).is_err(),
                "missing '{missing}' must be an error"
            );
        }
    }

    #[test]
    fn median_of_samples() {
        assert!(median_ms(&mut []).is_nan());
        assert_eq!(median_ms(&mut [3.0]), 3.0);
        assert_eq!(median_ms(&mut [9.0, 1.0, 4.0]), 4.0);
        assert_eq!(median_ms(&mut [8.0, 2.0, 4.0, 6.0]), 5.0);
        // an outlier rep doesn't move the median
        assert_eq!(median_ms(&mut [10.0, 11.0, 500.0]), 11.0);
    }
}
