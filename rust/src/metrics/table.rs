//! Speedup tables: assembly, markdown/CSV rendering, ASCII curves.
//!
//! A [`SpeedupTable`] is one paper figure: rows = scheduler configs,
//! columns = thread counts, cells = speedup over the serial baseline.

use std::fmt::Write as _;

/// One figure's worth of speedup data.
#[derive(Clone, Debug)]
pub struct SpeedupTable {
    pub title: String,
    pub threads: Vec<usize>,
    /// (config label, speedups per thread count)
    pub rows: Vec<(String, Vec<f64>)>,
}

impl SpeedupTable {
    pub fn new(title: &str, threads: Vec<usize>) -> Self {
        Self { title: title.to_string(), threads, rows: Vec::new() }
    }

    pub fn push_row(&mut self, label: String, speedups: Vec<f64>) {
        assert_eq!(speedups.len(), self.threads.len(), "row width mismatch");
        self.rows.push((label, speedups));
    }

    pub fn get(&self, label: &str, threads: usize) -> Option<f64> {
        let col = self.threads.iter().position(|&t| t == threads)?;
        let row = self.rows.iter().find(|(l, _)| l == label)?;
        Some(row.1[col])
    }

    /// Percent faster execution time of `better` vs `worse` at `threads`
    /// (the paper's gain metric: time ratio, not speedup ratio — they
    /// coincide for a common serial baseline).
    pub fn gain_pct(&self, better: &str, worse: &str, threads: usize) -> Option<f64> {
        let b = self.get(better, threads)?;
        let w = self.get(worse, threads)?;
        Some((1.0 - w / b) * 100.0)
    }

    /// GitHub-flavoured markdown.
    pub fn to_markdown(&self) -> String {
        let mut s = format!("### {}\n\n| config |", self.title);
        for t in &self.threads {
            let _ = write!(s, " {t} |");
        }
        s.push_str("\n|---|");
        for _ in &self.threads {
            s.push_str("---|");
        }
        s.push('\n');
        for (label, vals) in &self.rows {
            let _ = write!(s, "| {label} |");
            for v in vals {
                let _ = write!(s, " {v:.2} |");
            }
            s.push('\n');
        }
        s
    }

    /// CSV (config,threads,speedup long form — plot-friendly).
    pub fn to_csv(&self) -> String {
        let mut s = String::from("config,threads,speedup\n");
        for (label, vals) in &self.rows {
            for (t, v) in self.threads.iter().zip(vals) {
                let _ = writeln!(s, "{label},{t},{v:.4}");
            }
        }
        s
    }

    /// Terminal ASCII chart (one line per config, bars at the last column).
    pub fn to_ascii(&self) -> String {
        let max = self
            .rows
            .iter()
            .flat_map(|(_, v)| v.iter().copied())
            .fold(1.0_f64, f64::max);
        let mut s = format!("{}\n", self.title);
        for (label, vals) in &self.rows {
            let last = *vals.last().unwrap_or(&0.0);
            let bar_len = ((last / max) * 40.0).round() as usize;
            let _ = writeln!(
                s,
                "{:<26} {:>6.2}x |{}",
                label,
                last,
                "#".repeat(bar_len)
            );
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> SpeedupTable {
        let mut t = SpeedupTable::new("demo", vec![2, 4, 8]);
        t.push_row("wf-Scheduler".into(), vec![1.8, 3.5, 6.0]);
        t.push_row("wf-Scheduler-NUMA".into(), vec![1.9, 3.7, 6.6]);
        t
    }

    #[test]
    fn lookup_works() {
        let t = table();
        assert_eq!(t.get("wf-Scheduler", 4), Some(3.5));
        assert_eq!(t.get("wf-Scheduler", 3), None);
        assert_eq!(t.get("nope", 4), None);
    }

    #[test]
    fn gain_pct_matches_paper_semantics() {
        let t = table();
        // 6.6 vs 6.0 speedup => execution time ratio 6.0/6.6 => 9.09% faster
        let g = t.gain_pct("wf-Scheduler-NUMA", "wf-Scheduler", 8).unwrap();
        assert!((g - 9.0909).abs() < 0.01, "{g}");
    }

    #[test]
    fn markdown_has_all_cells() {
        let md = table().to_markdown();
        assert!(md.contains("| wf-Scheduler | 1.80 | 3.50 | 6.00 |"));
        assert!(md.contains("| 2 | 4 | 8 |"));
    }

    #[test]
    fn csv_long_form() {
        let csv = table().to_csv();
        assert!(csv.lines().count() == 1 + 6);
        assert!(csv.contains("wf-Scheduler-NUMA,8,6.6000"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn width_mismatch_panics() {
        let mut t = SpeedupTable::new("x", vec![2, 4]);
        t.push_row("r".into(), vec![1.0]);
    }
}
