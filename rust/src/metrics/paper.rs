//! The paper's published numbers, transcribed for side-by-side reporting.
//!
//! Every figure's text gives a handful of anchor values (max speedups at
//! 16 cores, relative gains).  `harness` prints measured-vs-paper for each
//! anchor; EXPERIMENTS.md records the deltas.  We target *shape*: ordering
//! of schedulers, collapse/crossover locations, gain signs and rough
//! magnitude — not absolute values (our substrate is a calibrated
//! simulator, not the authors' X4600; DESIGN.md §2).

/// An anchor value quoted in the paper.
#[derive(Clone, Copy, Debug)]
pub struct Anchor {
    /// figure id, e.g. "fig7"
    pub figure: &'static str,
    /// configuration label in paper legend style, e.g. "wf-Scheduler-NUMA"
    pub config: &'static str,
    pub threads: usize,
    /// speedup over serial quoted by the paper
    pub speedup: f64,
}

/// A relative-gain claim ("X runs N% faster than Y at 16 cores").
#[derive(Clone, Copy, Debug)]
pub struct GainClaim {
    pub figure: &'static str,
    pub bench: &'static str,
    pub better: &'static str,
    pub worse: &'static str,
    pub threads: usize,
    /// percent faster execution time
    pub pct: f64,
}

/// Speedup anchors quoted in §V / §VI prose.
pub const ANCHORS: &[Anchor] = &[
    // Fig 6 — SparseLU_for
    Anchor { figure: "fig6", config: "wf-Scheduler", threads: 16, speedup: 13.97 },
    // Fig 7 — FFT
    Anchor { figure: "fig7", config: "bf-Scheduler", threads: 6, speedup: 4.43 },
    Anchor { figure: "fig7", config: "bf-Scheduler", threads: 16, speedup: 2.39 },
    Anchor { figure: "fig7", config: "cilk-Scheduler", threads: 16, speedup: 8.61 },
    Anchor { figure: "fig7", config: "wf-Scheduler", threads: 16, speedup: 9.3 },
    Anchor { figure: "fig7", config: "cilk-Scheduler-NUMA", threads: 16, speedup: 9.92 },
    Anchor { figure: "fig7", config: "wf-Scheduler-NUMA", threads: 16, speedup: 11.09 },
    // Fig 8 — Strassen
    Anchor { figure: "fig8", config: "wf-Scheduler", threads: 16, speedup: 9.15 },
    Anchor { figure: "fig8", config: "cilk-Scheduler-NUMA", threads: 16, speedup: 8.13 },
    Anchor { figure: "fig8", config: "wf-Scheduler-NUMA", threads: 16, speedup: 10.27 },
    // Fig 9 — Sort
    Anchor { figure: "fig9", config: "wf-Scheduler", threads: 2, speedup: 1.86 },
    Anchor { figure: "fig9", config: "cilk-Scheduler", threads: 16, speedup: 5.49 },
    Anchor { figure: "fig9", config: "wf-Scheduler", threads: 16, speedup: 5.41 },
    // Fig 10 — NQueens
    Anchor { figure: "fig10", config: "bf-Scheduler", threads: 16, speedup: 15.93 },
    // Fig 13 — FFT with NUMA-aware schedulers
    Anchor { figure: "fig13", config: "dfwspt-Scheduler-NUMA", threads: 16, speedup: 11.78 },
    // Fig 14 — Sort
    Anchor { figure: "fig14", config: "dfwspt-Scheduler-NUMA", threads: 16, speedup: 6.32 },
    // Fig 15 — Strassen
    Anchor { figure: "fig15", config: "dfwsrpt-Scheduler-NUMA", threads: 16, speedup: 12.38 },
];

/// Relative-gain claims from the prose.
pub const GAINS: &[GainClaim] = &[
    GainClaim { figure: "fig5", bench: "floorplan", better: "cilk-Scheduler-NUMA", worse: "cilk-Scheduler", threads: 16, pct: 3.18 },
    GainClaim { figure: "fig5", bench: "floorplan", better: "wf-Scheduler-NUMA", worse: "wf-Scheduler", threads: 16, pct: 3.14 },
    GainClaim { figure: "fig6", bench: "sparselu_for", better: "wf-Scheduler-NUMA", worse: "wf-Scheduler", threads: 16, pct: 5.24 },
    GainClaim { figure: "fig6", bench: "sparselu_for", better: "cilk-Scheduler-NUMA", worse: "cilk-Scheduler", threads: 16, pct: 7.01 },
    GainClaim { figure: "fig9", bench: "sort", better: "cilk-Scheduler-NUMA", worse: "cilk-Scheduler", threads: 16, pct: 9.17 },
    GainClaim { figure: "fig9", bench: "sort", better: "wf-Scheduler-NUMA", worse: "wf-Scheduler", threads: 16, pct: 10.06 },
    GainClaim { figure: "fig10", bench: "nqueens", better: "bf-Scheduler-NUMA", worse: "bf-Scheduler", threads: 16, pct: 1.35 },
    GainClaim { figure: "fig13", bench: "fft", better: "dfwspt-Scheduler-NUMA", worse: "wf-Scheduler-NUMA", threads: 16, pct: 5.85 },
    GainClaim { figure: "fig14", bench: "sort", better: "dfwspt-Scheduler-NUMA", worse: "wf-Scheduler-NUMA", threads: 16, pct: 4.76 },
    GainClaim { figure: "fig15", bench: "strassen", better: "dfwsrpt-Scheduler-NUMA", worse: "wf-Scheduler-NUMA", threads: 16, pct: 17.03 },
];

/// Anchors for one figure.
pub fn anchors_for(figure: &str) -> Vec<Anchor> {
    ANCHORS.iter().copied().filter(|a| a.figure == figure).collect()
}

/// Gain claims for one figure.
pub fn gains_for(figure: &str) -> Vec<GainClaim> {
    GAINS.iter().copied().filter(|g| g.figure == figure).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchors_have_positive_speedups() {
        for a in ANCHORS {
            assert!(a.speedup > 0.0 && a.threads >= 2, "{a:?}");
        }
    }

    #[test]
    fn fig7_collapse_encoded() {
        let f = anchors_for("fig7");
        let bf6 = f.iter().find(|a| a.config == "bf-Scheduler" && a.threads == 6).unwrap();
        let bf16 = f.iter().find(|a| a.config == "bf-Scheduler" && a.threads == 16).unwrap();
        assert!(bf6.speedup > bf16.speedup, "the paper's bf collapse");
    }

    #[test]
    fn gains_positive() {
        for g in GAINS {
            assert!(g.pct > 0.0);
        }
    }
}
