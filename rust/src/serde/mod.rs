//! Self-contained (de)serialization layer — the crate's "serde".
//!
//! The vendored dependency set has no serde/serde_json/toml, so manifests
//! and run records flow through this module instead: a small [`Json`]
//! value type with a strict parser, a deterministic emitter (object keys
//! ordered, integral numbers printed as integers — byte-stable output for
//! the sweep CSV/JSON determinism guarantees), and a TOML-subset parser
//! ([`toml`]) that lowers `.toml` manifests onto the same [`Json`] tree so
//! every consumer handles one shape.
//!
//! Grown out of the JSON parser that previously lived in
//! `runtime::manifest` (which now re-exports from here).

pub mod toml;

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Context, Result};

/// 2^53: the first integer f64 cannot distinguish from its neighbour.
const F64_EXACT_INT_LIMIT: f64 = 9_007_199_254_740_992.0;

/// A parsed JSON/TOML value.  Objects use a [`BTreeMap`] so iteration —
/// and therefore emission — is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    /// Build an object from key/value pairs.
    pub fn obj<K: Into<String>>(pairs: impl IntoIterator<Item = (K, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.into(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_num(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Integral number as u64.  Rejects fractions, negatives, and values
    /// at or beyond 2^53 (where f64 can no longer represent every integer
    /// — accepting those would silently corrupt them; see
    /// [`Json::from_u64_lossless`] for the escape hatch).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && *n >= 0.0 && *n < F64_EXACT_INT_LIMIT => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    /// Encode a u64 without precision loss: values f64 can hold exactly
    /// stay numbers, larger ones become decimal strings.
    pub fn from_u64_lossless(n: u64) -> Json {
        if (n as f64) < F64_EXACT_INT_LIMIT {
            Json::Num(n as f64)
        } else {
            Json::Str(n.to_string())
        }
    }

    /// Read a u64 written by [`Json::from_u64_lossless`] (a number, or a
    /// decimal string for values beyond 2^53).
    pub fn as_u64_lossless(&self) -> Option<u64> {
        match self {
            Json::Str(s) => s.parse().ok(),
            _ => self.as_u64(),
        }
    }

    /// Integral number as usize.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().and_then(|n| usize::try_from(n).ok())
    }

    /// Compact single-line rendering.
    pub fn to_compact(&self) -> String {
        let mut s = String::new();
        self.emit(&mut s, None, 0);
        s
    }

    /// Pretty rendering with two-space indentation and a trailing newline.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.emit(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn emit(&self, out: &mut String, indent: Option<usize>, level: usize) {
        let pad = |out: &mut String, lvl: usize| {
            if let Some(w) = indent {
                out.push('\n');
                out.push_str(&" ".repeat(w * lvl));
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => emit_num(out, *n),
            Json::Str(s) => emit_str(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                        if indent.is_none() {
                            out.push(' ');
                        }
                    }
                    pad(out, level + 1);
                    item.emit(out, indent, level + 1);
                }
                if !items.is_empty() {
                    pad(out, level);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                        if indent.is_none() {
                            out.push(' ');
                        }
                    }
                    pad(out, level + 1);
                    emit_str(out, k);
                    out.push_str(": ");
                    v.emit(out, indent, level + 1);
                }
                if !map.is_empty() {
                    pad(out, level);
                }
                out.push('}');
            }
        }
    }
}

/// Deterministic number rendering: integral values print as integers
/// (seeds, thread counts, picosecond times survive a round-trip textually
/// unchanged), everything else via Rust's shortest-f64 formatting.
fn emit_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 9.0e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn emit_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}

impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}

impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}

impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}

impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", b as char, self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse::<f64>().with_context(|| format!("bad number '{s}'"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.bytes
                                    .get(self.pos + 1..self.pos + 5)
                                    .context("short \\u escape")?,
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(cp).context("bad codepoint")?);
                            self.pos += 4;
                        }
                        other => bail!("bad escape {:?}", other.map(|c| c as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => bail!("expected , or ] got {:?}", other.map(|c| c as char)),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => bail!("expected , or }} got {:?}", other.map(|c| c as char)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn json_rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{}x").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn json_nested() {
        let v = Json::parse(r#"{"a": [1, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[1].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn emit_parse_roundtrip() {
        let v = Json::obj([
            ("bench", Json::from("fft")),
            ("threads", Json::from(vec![Json::from(2u64), Json::from(16u64)])),
            ("seed", Json::from(42u64)),
            ("frac", Json::from(0.25)),
            ("name", Json::from("a\"b\\c\nd")),
            ("flag", Json::from(true)),
            ("nothing", Json::Null),
        ]);
        for text in [v.to_compact(), v.to_pretty()] {
            assert_eq!(Json::parse(&text).unwrap(), v, "{text}");
        }
    }

    #[test]
    fn emission_is_deterministic_and_ordered() {
        let a = Json::parse(r#"{"z": 1, "a": 2}"#).unwrap();
        let b = Json::parse(r#"{"a": 2, "z": 1}"#).unwrap();
        assert_eq!(a.to_compact(), b.to_compact());
        assert_eq!(a.to_compact(), r#"{"a": 2, "z": 1}"#);
    }

    #[test]
    fn integral_numbers_print_as_integers() {
        assert_eq!(Json::from(42u64).to_compact(), "42");
        assert_eq!(Json::Num(-3.0).to_compact(), "-3");
        assert_eq!(Json::from(0.5).to_compact(), "0.5");
    }

    #[test]
    fn u64_beyond_f64_precision_roundtrips_losslessly() {
        let big = u64::MAX - 1; // not representable in f64
        let j = Json::from_u64_lossless(big);
        assert_eq!(j, Json::Str(big.to_string()));
        assert_eq!(j.as_u64_lossless(), Some(big));
        // small values stay plain numbers
        assert_eq!(Json::from_u64_lossless(42), Json::Num(42.0));
        assert_eq!(Json::Num(42.0).as_u64_lossless(), Some(42));
        // a huge *numeric* literal is rejected rather than silently rounded
        assert_eq!(Json::parse("9007199254740993").unwrap().as_u64(), None);
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 8, "f": 1.5, "b": true}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_u64(), Some(8));
        assert_eq!(v.get("n").unwrap().as_usize(), Some(8));
        assert_eq!(v.get("f").unwrap().as_u64(), None);
        assert_eq!(v.get("b").unwrap().as_bool(), Some(true));
        assert_eq!(Json::Num(-1.0).as_u64(), None);
    }
}
