//! TOML-subset parser lowering onto the [`Json`](super::Json) tree.
//!
//! Covers what experiment manifests need — and nothing more:
//!
//! * `key = value` pairs with bare, quoted, or dotted keys;
//! * basic (`"…"`, with escapes) and literal (`'…'`) strings;
//! * integers (with `_` separators), floats, booleans;
//! * arrays, including multi-line and nested ones, with trailing commas;
//! * inline tables `{ k = v, … }`;
//! * `[table]` and `[[array-of-tables]]` headers, with dotted paths
//!   (a path segment that is an array of tables resolves to its last
//!   element, as in real TOML);
//! * `#` comments.
//!
//! Unsupported (errors, never silent misparses): multi-line strings,
//! dates/times.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use super::Json;

/// Parse a TOML document into a [`Json::Obj`] tree.
pub fn parse(text: &str) -> Result<Json> {
    let mut root = BTreeMap::new();
    let mut current_path: Vec<String> = Vec::new();

    let mut lines = text.lines().enumerate().peekable();
    while let Some((lineno, raw)) = lines.next() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        let err_ctx = || format!("TOML line {}", lineno + 1);

        if let Some(inner) = line.strip_prefix("[[") {
            let path_str = inner.strip_suffix("]]").with_context(err_ctx).context("expected ]]")?;
            let path = parse_key_path(path_str).with_context(err_ctx)?;
            let (last, parents) = path.split_last().context("empty table path")?;
            let parent = navigate(&mut root, parents).with_context(err_ctx)?;
            match parent.entry(last.clone()).or_insert_with(|| Json::Arr(Vec::new())) {
                Json::Arr(items) => items.push(Json::Obj(BTreeMap::new())),
                _ => bail!("{}: [[{path_str}]] conflicts with a non-array value", err_ctx()),
            }
            current_path = path;
        } else if let Some(inner) = line.strip_prefix('[') {
            let path_str = inner.strip_suffix(']').with_context(err_ctx).context("expected ]")?;
            let path = parse_key_path(path_str).with_context(err_ctx)?;
            navigate(&mut root, &path).with_context(err_ctx)?;
            current_path = path;
        } else {
            // key = value (value may continue over following lines while
            // brackets stay open)
            let eq = find_unquoted(&line, '=').with_context(err_ctx).context("expected key = value")?;
            let key_part = line[..eq].trim().to_string();
            let mut value_part = line[eq + 1..].trim().to_string();
            while bracket_balance(&value_part)? > 0 {
                let (_, cont) = lines.next().with_context(err_ctx).context("unclosed array")?;
                value_part.push('\n');
                value_part.push_str(strip_comment(cont).trim_end());
            }
            let key_path = parse_key_path(&key_part).with_context(err_ctx)?;
            let value = parse_value_str(value_part.trim()).with_context(err_ctx)?;

            let full: Vec<String> =
                current_path.iter().chain(key_path.iter()).cloned().collect();
            let (last, parents) = full.split_last().unwrap();
            let table = navigate(&mut root, parents).with_context(err_ctx)?;
            if table.insert(last.clone(), value).is_some() {
                bail!("{}: duplicate key '{last}'", err_ctx());
            }
        }
    }
    Ok(Json::Obj(root))
}

/// Walk (creating as needed) to the table at `path`; an array-of-tables
/// segment resolves to its last element.
fn navigate<'a>(
    root: &'a mut BTreeMap<String, Json>,
    path: &[String],
) -> Result<&'a mut BTreeMap<String, Json>> {
    let mut cur = root;
    for seg in path {
        let next = cur.entry(seg.clone()).or_insert_with(|| Json::Obj(BTreeMap::new()));
        cur = match next {
            Json::Obj(m) => m,
            Json::Arr(items) => match items.last_mut() {
                Some(Json::Obj(m)) => m,
                _ => bail!("path segment '{seg}' is not a table array"),
            },
            _ => bail!("path segment '{seg}' is not a table"),
        };
    }
    Ok(cur)
}

/// `a.b."c d"` → ["a", "b", "c d"].
fn parse_key_path(s: &str) -> Result<Vec<String>> {
    let s = s.trim();
    if s.is_empty() {
        bail!("empty key");
    }
    let mut out = Vec::new();
    let mut chars = s.chars().peekable();
    loop {
        let seg = match chars.peek() {
            Some('"') | Some('\'') => {
                let quote = chars.next().unwrap();
                let mut seg = String::new();
                loop {
                    match chars.next() {
                        None => bail!("unterminated quoted key"),
                        Some(c) if c == quote => break,
                        Some(c) => seg.push(c),
                    }
                }
                seg
            }
            _ => {
                let mut seg = String::new();
                while let Some(&c) = chars.peek() {
                    if c == '.' {
                        break;
                    }
                    if !(c.is_ascii_alphanumeric() || c == '_' || c == '-') {
                        bail!("bad character '{c}' in bare key '{s}'");
                    }
                    seg.push(c);
                    chars.next();
                }
                if seg.is_empty() {
                    bail!("empty key segment in '{s}'");
                }
                seg
            }
        };
        out.push(seg);
        match chars.next() {
            None => return Ok(out),
            Some('.') => continue,
            Some(c) => bail!("unexpected '{c}' after key segment"),
        }
    }
}

/// Remove a `#` comment, honouring strings (including `\"` escapes).
fn strip_comment(line: &str) -> &str {
    let mut quote: Option<char> = None;
    let mut escaped = false;
    for (i, c) in line.char_indices() {
        if escaped {
            escaped = false;
            continue;
        }
        match (quote, c) {
            (None, '#') => return &line[..i],
            (None, '"') | (None, '\'') => quote = Some(c),
            (Some('"'), '\\') => escaped = true,
            (Some(q), c) if c == q => quote = None,
            _ => {}
        }
    }
    line
}

/// Net `[`/`{` depth outside strings (for multi-line array detection).
fn bracket_balance(s: &str) -> Result<i32> {
    let mut depth = 0i32;
    let mut quote: Option<char> = None;
    let mut escaped = false;
    for c in s.chars() {
        if let Some(q) = quote {
            if escaped {
                escaped = false;
            } else if q == '"' && c == '\\' {
                escaped = true;
            } else if c == q {
                quote = None;
            }
            continue;
        }
        match c {
            '"' | '\'' => quote = Some(c),
            '[' | '{' => depth += 1,
            ']' | '}' => depth -= 1,
            _ => {}
        }
    }
    if quote.is_some() {
        bail!("unterminated string");
    }
    Ok(depth)
}

/// First unquoted occurrence of `needle`.
fn find_unquoted(s: &str, needle: char) -> Option<usize> {
    let mut quote: Option<char> = None;
    for (i, c) in s.char_indices() {
        match (quote, c) {
            (None, c) if c == needle => return Some(i),
            (None, '"') | (None, '\'') => quote = Some(c),
            (Some(q), c) if c == q => quote = None,
            _ => {}
        }
    }
    None
}

fn parse_value_str(s: &str) -> Result<Json> {
    let mut cur = Cursor { chars: s.chars().collect(), pos: 0 };
    let v = cur.value()?;
    cur.skip_ws();
    if cur.pos != cur.chars.len() {
        bail!("trailing garbage after value in '{s}'");
    }
    Ok(v)
}

struct Cursor {
    chars: Vec<char>,
    pos: usize,
}

impl Cursor {
    fn peek(&self) -> Option<char> {
        self.chars.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(c) if c.is_whitespace()) {
            self.pos += 1;
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            None => bail!("missing value"),
            Some('"') => self.basic_string(),
            Some('\'') => self.literal_string(),
            Some('[') => self.array(),
            Some('{') => self.inline_table(),
            Some(c) if c.is_ascii_alphabetic() => self.keyword(),
            Some(_) => self.number(),
        }
    }

    fn keyword(&mut self) -> Result<Json> {
        let start = self.pos;
        while matches!(self.peek(), Some(c) if c.is_ascii_alphabetic()) {
            self.pos += 1;
        }
        let word: String = self.chars[start..self.pos].iter().collect();
        match word.as_str() {
            "true" => Ok(Json::Bool(true)),
            "false" => Ok(Json::Bool(false)),
            other => bail!("unsupported TOML value '{other}'"),
        }
    }

    fn basic_string(&mut self) -> Result<Json> {
        self.pos += 1; // opening quote
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some('"') => {
                    self.pos += 1;
                    return Ok(Json::Str(out));
                }
                Some('\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some('n') => out.push('\n'),
                        Some('t') => out.push('\t'),
                        Some('r') => out.push('\r'),
                        Some('"') => out.push('"'),
                        Some('\\') => out.push('\\'),
                        Some('u') => {
                            let hex: String = self
                                .chars
                                .get(self.pos + 1..self.pos + 5)
                                .context("short \\u escape")?
                                .iter()
                                .collect();
                            let cp = u32::from_str_radix(&hex, 16)?;
                            out.push(char::from_u32(cp).context("bad codepoint")?);
                            self.pos += 4;
                        }
                        other => bail!("bad escape {other:?}"),
                    }
                    self.pos += 1;
                }
                Some(c) => {
                    out.push(c);
                    self.pos += 1;
                }
            }
        }
    }

    fn literal_string(&mut self) -> Result<Json> {
        self.pos += 1;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated literal string"),
                Some('\'') => {
                    self.pos += 1;
                    return Ok(Json::Str(out));
                }
                Some(c) => {
                    out.push(c);
                    self.pos += 1;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, '-' | '+' | '.' | 'e' | 'E' | '_')
        ) {
            self.pos += 1;
        }
        let raw: String =
            self.chars[start..self.pos].iter().filter(|&&c| c != '_').collect();
        Ok(Json::Num(raw.parse::<f64>().with_context(|| format!("bad number '{raw}'"))?))
    }

    fn array(&mut self) -> Result<Json> {
        self.pos += 1; // [
        let mut items = Vec::new();
        loop {
            self.skip_ws();
            match self.peek() {
                None => bail!("unterminated array"),
                Some(']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => {
                    items.push(self.value()?);
                    self.skip_ws();
                    match self.peek() {
                        Some(',') => {
                            self.pos += 1;
                        }
                        Some(']') => {}
                        other => bail!("expected , or ] got {other:?}"),
                    }
                }
            }
        }
    }

    fn inline_table(&mut self) -> Result<Json> {
        self.pos += 1; // {
        let mut map = BTreeMap::new();
        loop {
            self.skip_ws();
            match self.peek() {
                None => bail!("unterminated inline table"),
                Some('}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => {
                    let key_start = self.pos;
                    while matches!(self.peek(), Some(c) if c != '=') {
                        self.pos += 1;
                    }
                    let key: String =
                        self.chars[key_start..self.pos].iter().collect::<String>().trim().to_string();
                    if key.is_empty() {
                        bail!("empty key in inline table");
                    }
                    self.pos += 1; // =
                    let val = self.value()?;
                    map.insert(key, val);
                    self.skip_ws();
                    match self.peek() {
                        Some(',') => {
                            self.pos += 1;
                        }
                        Some('}') => {}
                        other => bail!("expected , or }} got {other:?}"),
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_and_tables() {
        let t = parse(
            "title = \"demo\" # comment\n\
             count = 42\n\
             ratio = 2.5\n\
             big = 1_000\n\
             on = true\n\
             [defaults]\n\
             size = 'small'\n",
        )
        .unwrap();
        assert_eq!(t.get("title").unwrap().as_str(), Some("demo"));
        assert_eq!(t.get("count").unwrap().as_u64(), Some(42));
        assert_eq!(t.get("ratio").unwrap().as_num(), Some(2.5));
        assert_eq!(t.get("big").unwrap().as_u64(), Some(1000));
        assert_eq!(t.get("on").unwrap().as_bool(), Some(true));
        assert_eq!(t.get("defaults").unwrap().get("size").unwrap().as_str(), Some("small"));
    }

    #[test]
    fn array_of_tables_and_multiline_arrays() {
        let t = parse(
            "[[sweeps]]\n\
             id = \"a\"\n\
             threads = [\n  2, 4, # inline comment\n  8,\n]\n\
             [sweeps.cost]\n\
             dram_base_ns = 100\n\
             [[sweeps]]\n\
             id = \"b\"\n\
             bench = [\"fft\", \"sort\"]\n",
        )
        .unwrap();
        let sweeps = t.get("sweeps").unwrap().as_arr().unwrap();
        assert_eq!(sweeps.len(), 2);
        assert_eq!(sweeps[0].get("id").unwrap().as_str(), Some("a"));
        let threads: Vec<u64> = sweeps[0]
            .get("threads")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_u64().unwrap())
            .collect();
        assert_eq!(threads, vec![2, 4, 8]);
        assert_eq!(
            sweeps[0].get("cost").unwrap().get("dram_base_ns").unwrap().as_u64(),
            Some(100)
        );
        assert_eq!(sweeps[1].get("bench").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn inline_tables_and_dotted_keys() {
        let t = parse("cost = { dram_base_ns = 100, hop_penalty_ns = 40 }\na.b = 1\n").unwrap();
        assert_eq!(
            t.get("cost").unwrap().get("hop_penalty_ns").unwrap().as_u64(),
            Some(40)
        );
        assert_eq!(t.get("a").unwrap().get("b").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn errors_are_loud() {
        assert!(parse("key").is_err());
        assert!(parse("k = [1, 2").is_err());
        assert!(parse("k = 1\nk = 2").is_err());
        assert!(parse("k = 1979-05-27").is_err());
        assert!(parse("bad key = 1").is_err());
    }

    #[test]
    fn strings_with_hash_and_quotes() {
        let t = parse("a = \"x # not a comment\"\nb = 'lit \\n raw'\n").unwrap();
        assert_eq!(t.get("a").unwrap().as_str(), Some("x # not a comment"));
        assert_eq!(t.get("b").unwrap().as_str(), Some("lit \\n raw"));
    }
}
