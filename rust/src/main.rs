//! `numanos` — CLI launcher for the NUMA-aware task-runtime reproduction.
//!
//! ```text
//! numanos list                         # benchmarks / schedulers / topologies
//! numanos topo   --name x4600          # fabric + §IV priorities
//! numanos run    --bench fft --sched dfwspt --bind numa --threads 16
//! numanos figure --id fig7             # regenerate one paper figure
//! numanos figure --all --out results/  # regenerate all nine figures
//! numanos gains                        # §V.A NUMA-allocation gain summary
//! ```

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use numanos::bots;
use numanos::config::{parse_cost_overrides, ComputeMode, RunConfig, Size};
use numanos::coordinator::priority::core_priorities;
use numanos::coordinator::runtime::Runtime;
use numanos::coordinator::sched::Policy;
use numanos::harness;
use numanos::metrics::speedup;
use numanos::runtime::ExecEngine;
use numanos::simnuma::CostModel;
use numanos::topology::Topology;
use numanos::util::fmt_time;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Parse `--k v` flags into a map; returns (subcommand, flags).
fn parse_args() -> Result<(String, HashMap<String, String>)> {
    let mut args = std::env::args().skip(1);
    let cmd = args.next().unwrap_or_else(|| "help".into());
    let mut flags = HashMap::new();
    let mut key: Option<String> = None;
    for a in args {
        if let Some(stripped) = a.strip_prefix("--") {
            if let Some(k) = key.take() {
                flags.insert(k, "true".into()); // boolean flag
            }
            key = Some(stripped.to_string());
        } else if let Some(k) = key.take() {
            flags.insert(k, a);
        } else {
            bail!("unexpected positional argument '{a}'");
        }
    }
    if let Some(k) = key.take() {
        flags.insert(k, "true".into());
    }
    Ok((cmd, flags))
}

fn run() -> Result<()> {
    let (cmd, flags) = parse_args()?;
    match cmd.as_str() {
        "list" => cmd_list(),
        "topo" => cmd_topo(&flags),
        "run" => cmd_run(&flags),
        "figure" => cmd_figure(&flags),
        "gains" => cmd_gains(&flags),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `numanos help`)"),
    }
}

const HELP: &str = "\
numanos — NUMA-aware OpenMP task runtime (Tahan 2014 reproduction)

commands:
  list                      benchmarks, schedulers, topologies
  topo   --name <topo>      fabric, hop matrix, and SS IV core priorities
  run    --bench <b> [--size s|m|l] [--sched P] [--bind linear|numa]
         [--threads N] [--topo T] [--seed S] [--compute sim|pjrt]
         [--cost k=v,...]   single run, prints the stats summary
  figure --id figN | --all  regenerate paper figures (speedup tables)
         [--out dir] [--size s|m|l] [--seed S] [--cost k=v,...]
  gains  [--size s|m|l]     SS V.A NUMA-allocation gain summary
";

fn cmd_list() -> Result<()> {
    println!("benchmarks : {}", bots::NAMES.join(" "));
    println!(
        "schedulers : {}",
        Policy::all().iter().map(|p| p.name()).collect::<Vec<_>>().join(" ")
    );
    println!("bindings   : linear numa");
    println!("topologies : {}", Topology::preset_names().join(" "));
    println!("figures    : {}", harness::figures().iter().map(|f| f.id).collect::<Vec<_>>().join(" "));
    Ok(())
}

fn cmd_topo(flags: &HashMap<String, String>) -> Result<()> {
    let name = flags.get("name").map(String::as_str).unwrap_or("x4600");
    let topo = Topology::by_name(name)?;
    println!(
        "{}: {} nodes, {} cores, max {} hops, {} pages/node",
        topo.name(),
        topo.num_nodes(),
        topo.num_cores(),
        topo.max_hops(),
        topo.node_capacity_pages()
    );
    println!("\nnode hop matrix:");
    for a in 0..topo.num_nodes() {
        let row: Vec<String> =
            (0..topo.num_nodes()).map(|b| topo.node_hops(a, b).to_string()).collect();
        println!("  node {a:>2}: {}  (mean hops to cores: {:.2})", row.join(" "), topo.mean_hops_from(a));
    }
    let pr = core_priorities(&topo);
    println!("\nSS IV core priorities (alpha = {:?}):", pr.alpha);
    let ranked = pr.ranked_cores();
    for &c in &ranked {
        println!(
            "  core {c:>2} (node {}): P1 = {:8.2}  P = {:10.2}{}",
            topo.node_of(c),
            pr.p1[c],
            pr.scores[c],
            if c == ranked[0] { "   <- master binds here" } else { "" }
        );
    }
    Ok(())
}

fn build_runtime(flags: &HashMap<String, String>, topo_name: &str) -> Result<Runtime> {
    let topo = Topology::by_name(topo_name)?;
    let mut cost = CostModel::default();
    if let Some(spec) = flags.get("cost") {
        parse_cost_overrides(&mut cost, spec)?;
    }
    Ok(Runtime::new(topo, cost))
}

fn cmd_run(flags: &HashMap<String, String>) -> Result<()> {
    let mut cfg = RunConfig::default();
    for key in ["bench", "size", "sched", "bind", "threads", "topo", "seed", "compute", "artifacts"]
    {
        if let Some(v) = flags.get(key) {
            cfg.set(key, v)?;
        }
    }
    let rt = build_runtime(flags, &cfg.topo)?;
    println!("# {}", cfg.describe());
    let mut workload = bots::create(&cfg.bench, cfg.size, cfg.seed)?;

    let mut exec = match cfg.compute {
        ComputeMode::Pjrt => {
            let e = ExecEngine::cpu(&cfg.artifact_dir)?;
            println!("# pjrt platform: {} ({} artifacts)", e.platform(), e.manifest_len());
            Some(e)
        }
        ComputeMode::Sim => None,
    };

    // serial baseline for the speedup line
    let mut serial_w = bots::create(&cfg.bench, cfg.size, cfg.seed)?;
    let serial = rt.run_serial(serial_w.as_mut(), cfg.seed)?;

    let stats = rt.run(
        workload.as_mut(),
        cfg.policy,
        cfg.bind,
        cfg.threads,
        cfg.seed,
        exec.as_mut(),
    )?;
    println!("{}", stats.summary());
    println!(
        "mem: l1={} l2={} miss={} (hops {:.2}) stall={} work={} overhead={}",
        stats.mem.l1_hit_lines,
        stats.mem.l2_hit_lines,
        stats.mem.miss_lines(),
        stats.mem.mean_miss_hops(),
        fmt_time(stats.mem.contention_stall),
        fmt_time(stats.work_time),
        fmt_time(stats.overhead_time),
    );
    println!(
        "serial {} -> speedup {:.2}x | efficiency {:.1}% | events {} | host {:.1} ms",
        fmt_time(serial.makespan),
        speedup(&serial, &stats),
        100.0 * stats.efficiency(),
        stats.sim_events,
        stats.wall_ms,
    );
    if let Some(e) = &exec {
        println!("pjrt kernel calls: {} (verified)", e.calls);
    }
    Ok(())
}

fn cmd_figure(flags: &HashMap<String, String>) -> Result<()> {
    let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(42);
    let size = flags
        .get("size")
        .map(|s| Size::from_name(s))
        .transpose()?
        .unwrap_or(Size::Medium);
    let rt = build_runtime(flags, flags.get("topo").map(String::as_str).unwrap_or("x4600"))?;
    let specs: Vec<harness::FigureSpec> = if flags.contains_key("all") {
        harness::figures()
    } else if let Some(id) = flags.get("id") {
        vec![harness::figure_by_id(id).with_context(|| format!("unknown figure '{id}'"))?]
    } else {
        bail!("figure: need --id <figN> or --all");
    };
    let out_dir = flags.get("out").cloned();
    if let Some(d) = &out_dir {
        std::fs::create_dir_all(d)?;
    }
    for mut spec in specs {
        spec.size = size;
        let t0 = std::time::Instant::now();
        let table = harness::run_figure(&rt, &spec, seed)?;
        let rep = harness::report(&spec, &table);
        println!("{rep}");
        println!("{}", table.to_ascii());
        eprintln!("[{} took {:.1}s]", spec.id, t0.elapsed().as_secs_f64());
        if let Some(d) = &out_dir {
            std::fs::write(format!("{d}/{}.md", spec.id), &rep)?;
            std::fs::write(format!("{d}/{}.csv", spec.id), table.to_csv())?;
        }
    }
    Ok(())
}

fn cmd_gains(flags: &HashMap<String, String>) -> Result<()> {
    let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(42);
    let size = flags
        .get("size")
        .map(|s| Size::from_name(s))
        .transpose()?
        .unwrap_or(Size::Medium);
    let rt = build_runtime(flags, "x4600")?;
    let table = harness::gains_summary(&rt, size, seed)?;
    println!("{}", table.to_markdown());
    Ok(())
}
