//! `numanos` — CLI launcher for the NUMA-aware task-runtime reproduction.
//!
//! ```text
//! numanos list                         # benchmarks / schedulers / mem policies / bindings / topologies
//! numanos topo   --name x4600          # fabric + §IV priorities
//! numanos run    --bench fft --sched dfwspt --bind numa --threads 16
//! numanos run    --bench=fft --json    # --flag=value syntax, JSON record
//! numanos figure --id fig7             # regenerate one paper figure
//! numanos figure --all --out results/  # regenerate all nine figures
//! numanos gains                        # §V.A NUMA-allocation gain summary
//! numanos sweep  --manifest exp.toml   # run a user-authored experiment file
//! numanos sweep  --manifest exp.toml --store store/   # cached cells skip execution
//! numanos serve  --store store/ --spool spool/ --once # manifest spool service
//! numanos bench  --out BENCH_7.json    # run the pinned perf-trajectory suite
//! numanos bench  --compare BENCH_6.json BENCH_7.json   # delta report
//! numanos vet    --all                 # scheduler contract checker (VET0xx diagnostics)
//! numanos lint   --dir examples/       # static manifest / config / store linter
//! ```
//!
//! Everything execution-shaped goes through the [`spec`](numanos::spec)
//! layer: `run` builds one validated [`RunSpec`], `figure`/`gains`/`sweep`
//! expand [`Sweep`] grids on a shared [`Session`] (memoized serial
//! baselines, cells in parallel across OS threads, deterministic output).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use numanos::analysis;
use numanos::bench;
use numanos::bots;
use numanos::config::Size;
use numanos::coordinator::priority::core_priorities;
use numanos::coordinator::sched;
use numanos::harness;
use numanos::serde::Json;
use numanos::simnuma::CostModel;
use numanos::spec::session::default_workers;
use numanos::spec::{parse_cost_pairs, ExperimentManifest, RunSpec, Session, ShardPlan};
use numanos::store::{serve, shard, ResultStore};
use numanos::topology::Topology;
use numanos::util::fmt_time;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Per-command flag inventory: (command, flags taking a value, boolean
/// flags, positional arguments accepted).  Only `bench --compare` (the
/// two report files) and `vet` (a scheduler name) take positionals;
/// everywhere else a bare token stays a clear error.
const COMMANDS: &[(&str, &[&str], &[&str], usize)] = &[
    ("list", &[], &[], 0),
    ("topo", &["name"], &[], 0),
    (
        "run",
        &[
            "bench", "size", "sched", "policy", "mem", "bind", "cores", "threads", "topo",
            "seed", "compute", "artifacts", "cost", "rtdata",
        ],
        &["json", "checked"],
        0,
    ),
    ("figure", &["id", "out", "size", "seed", "topo", "cost"], &["all", "json"], 0),
    ("gains", &["size", "seed", "cost"], &["json"], 0),
    (
        "sweep",
        &["manifest", "out", "workers", "seed", "store", "shard"],
        &["json", "seq", "resume", "no-cache", "checked"],
        0,
    ),
    (
        "merge",
        &["manifest", "store", "out", "workers", "seed"],
        &["json", "seq", "merge-strict", "checked"],
        0,
    ),
    ("serve", &["store", "spool", "poll-ms", "workers"], &["once"], 0),
    (
        "bench",
        &["out", "reps", "filter", "max-regress-pct", "wall-warn-pct"],
        &["compare", "json", "warn-only", "fail-on-drift", "checked"],
        2,
    ),
    ("vet", &[], &["all", "json"], 1),
    ("lint", &["manifest", "dir"], &["json"], 0),
    ("help", &[], &[], 0),
];

/// Parse `--key value` / `--key=value` / boolean `--flag` arguments,
/// validated against the command's flag inventory.  Unknown flags are
/// collected and reported together; a value-less flag that needs a value
/// is a clear error instead of a silently-misparsed `"true"`.
fn parse_args() -> Result<(String, HashMap<String, String>, Vec<String>)> {
    let mut args = std::env::args().skip(1).peekable();
    let cmd = args.next().unwrap_or_else(|| "help".into());
    let cmd = match cmd.as_str() {
        "--help" | "-h" => "help".to_string(),
        _ => cmd,
    };
    let (_, value_flags, bool_flags, max_positionals) = COMMANDS
        .iter()
        .find(|(name, _, _, _)| *name == cmd)
        .ok_or_else(|| anyhow::anyhow!("unknown command '{cmd}' (try `numanos help`)"))?;

    let mut flags = HashMap::new();
    let mut positionals: Vec<String> = Vec::new();
    let mut unknown: Vec<String> = Vec::new();
    while let Some(a) = args.next() {
        let Some(stripped) = a.strip_prefix("--") else {
            if positionals.len() < *max_positionals {
                positionals.push(a);
                continue;
            }
            bail!("unexpected positional argument '{a}' (flags are --key value or --key=value)");
        };
        let (key, explicit_value) = match stripped.split_once('=') {
            Some((k, v)) => (k.to_string(), Some(v.to_string())),
            None => (stripped.to_string(), None),
        };
        let is_value = value_flags.contains(&key.as_str());
        let is_bool = bool_flags.contains(&key.as_str());
        if !is_value && !is_bool {
            unknown.push(format!("--{key}"));
            // swallow the unknown flag's value so it isn't misread as a
            // positional; the aggregated unknown-flag error reports it
            if explicit_value.is_none()
                && matches!(args.peek(), Some(next) if !next.starts_with("--"))
            {
                args.next();
            }
            continue;
        }
        // only value flags consume a following token; booleans never do
        // (`figure --all fig7` is a positional error, not a discarded token)
        let value = match (explicit_value, is_value) {
            (Some(v), true) => v,
            (Some(v), false) => match v.as_str() {
                "true" | "false" => v,
                other => bail!("flag '--{key}' is boolean; got '--{key}={other}'"),
            },
            (None, true) => {
                let has_value = matches!(args.peek(), Some(next) if !next.starts_with("--"));
                if !has_value {
                    bail!("flag '--{key}' expects a value (--{key} <v> or --{key}=<v>)");
                }
                args.next().unwrap()
            }
            (None, false) => "true".to_string(),
        };
        if flags.insert(key.clone(), value).is_some() {
            bail!("flag '--{key}' given more than once");
        }
    }
    if !unknown.is_empty() {
        let mut allowed: Vec<String> = value_flags
            .iter()
            .chain(bool_flags.iter())
            .map(|f| format!("--{f}"))
            .collect();
        allowed.sort();
        bail!(
            "unknown flag(s) for '{cmd}': {} (allowed: {})",
            unknown.join(", "),
            if allowed.is_empty() { "none".to_string() } else { allowed.join(" ") }
        );
    }
    Ok((cmd, flags, positionals))
}

/// A boolean flag is set only when its value is literally "true"
/// (`--json=false` disables it).
fn bool_flag(flags: &HashMap<String, String>, key: &str) -> bool {
    flags.get(key).map(|v| v == "true").unwrap_or(false)
}

fn run() -> Result<()> {
    let (cmd, flags, positionals) = parse_args()?;
    match cmd.as_str() {
        "list" => cmd_list(),
        "topo" => cmd_topo(&flags),
        "run" => cmd_run(&flags),
        "figure" => cmd_figure(&flags),
        "gains" => cmd_gains(&flags),
        "sweep" => cmd_sweep(&flags),
        "merge" => cmd_merge(&flags),
        "serve" => cmd_serve(&flags),
        "bench" => cmd_bench(&flags, &positionals),
        "vet" => cmd_vet(&flags, &positionals),
        "lint" => cmd_lint(&flags),
        "help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `numanos help`)"),
    }
}

const HELP: &str = "\
numanos — NUMA-aware OpenMP task runtime (Tahan 2014 reproduction)

commands:
  list                      benchmarks, schedulers, mem policies, bindings, topologies
  topo   --name <topo>      fabric, hop matrix, and SS IV core priorities
  run    --bench <b> [--size s|m|l] [--sched P] [--mem M]
         [--bind linear|numa] [--cores 0,2,4] [--threads N] [--topo T]
         [--seed S] [--compute sim|pjrt] [--cost k=v,...] [--json]
                            single run, prints the stats summary
                            (--sched takes any registered scheduler,
                             parameterized as name:k=v,... e.g.
                             --sched hops-threshold:max_hops=1;
                             --mem takes a page policy: first-touch,
                             interleave, bind:node=N, next-touch
                             [:max_moves=N] — pair --mem with
                             --sched numa-home for push-to-home placement,
                             or --sched numa-steal for steal-side-only
                             locality bias)
  figure --id figN | --all  regenerate paper figures (speedup tables)
         [--out dir] [--size s|m|l] [--seed S] [--topo T] [--cost k=v,...]
         [--json]
  gains  [--size s|m|l] [--seed S] [--cost k=v,...]
                            SS V.A NUMA-allocation gain summary
  sweep  --manifest <file>  run a JSON/TOML experiment manifest
         [--out dir] [--json] [--seq] [--workers N] [--seed S]
         [--store dir]       persistent content-addressed result store:
                             cached cells skip execution (read-through),
                             executed cells are written through; the
                             per-sweep summary reports hit/miss/written
         [--resume]          require an existing --store (continue an
                             interrupted sweep from its records)
         [--no-cache]        with --store: re-execute every cell but
                             refresh the store's records
         [--shard I/N]       execute only the cells whose global index
                             ≡ I (mod N) — deterministic partition of
                             the flattened cell sequence, stable across
                             processes; needs --store (that's where the
                             records land) and publishes a completion
                             marker under <store>/shards/; assemble the
                             full output with `numanos merge`
  merge  --manifest <file> --store <dir>
         [--out dir] [--json] [--seq] [--workers N] [--seed S]
                            assemble sharded sweeps: re-run the full
                            manifest against the shards' shared store
                            (100% cache hits when every shard finished)
                            and emit CSV/JSON byte-identical to a
                            sequential single-process sweep; reports
                            the shard-marker census first
         [--merge-strict]    fail on missing/stale shard markers or any
                             cache miss instead of re-executing cells
  serve  --store <dir> --spool <dir> [--poll-ms N] [--workers N] [--once]
                            watch the spool for dropped manifest files,
                            execute each through the shared store, write
                            <job>.result.json + <job>.receipt.json
                            (manifest FNV hash, per-sweep hit/miss
                            counts, wall time), then move the job to
                            done/ or failed/; --once drains the backlog
                            (to a fixpoint, so fanned-out work finishes
                            too) and exits; a job carrying \"shards\": N
                            fans out into N shard items plus a merge
                            item gated on their receipts — a
                            hostfile-free multi-process driver
  bench  [--filter G] [--reps N] [--out file.json] [--json]
                            run the pinned perf-trajectory suite (paper
                            figures + strategy ablation + hot-loop
                            cells) and write a BENCH_*.json report
                            (simulated metrics + host wall-time medians)
  bench  --compare <old.json> <new.json> [--max-regress-pct P]
         [--wall-warn-pct P] [--warn-only] [--fail-on-drift] [--json]
                            per-benchmark delta table; exits non-zero
                            when simulated makespan regresses past the
                            threshold (wall-time drift only warns)
  vet    [scheduler] | --all [--json]
                            scheduler contract checker: drives hooks
                            through synthetic probe contexts and reports
                            VET0xx diagnostics (see README \"Static
                            analysis & vetting\"); exits non-zero on any
                            error-severity finding
  lint   --manifest <file> | --dir <dir> [--json]
                            static linter for experiment manifests,
                            key=value run configs, and store indexes:
                            LINT0xx diagnostics without executing a cell

run/sweep/merge/bench also accept --checked: the engine verifies its internal
invariants (CHK0xx) after every event and aborts with a structured
report on violation; results are byte-identical to unchecked runs.

flags accept both `--key value` and `--key=value`.
";

/// The sweep axes (benchmarks, schedulers, page policies, bindings,
/// topologies) plus the figure inventory — one line each.  The scheduler
/// line comes from the registry, so registered strategies appear
/// automatically; the page-policy line shows declared parameters with
/// their defaults.
fn cmd_list() -> Result<()> {
    println!("benchmarks : {}", bots::NAMES.join(" "));
    // schedulers carry their declared tunables with defaults, like the
    // page-policy line: `numa-home(min_kb=16;steal_bias=1;…)` reads as
    // "parameters and what you get without overrides"
    let scheds: Vec<String> = sched::scheduler_infos()
        .iter()
        .map(|info| {
            if info.params.is_empty() {
                info.name.clone()
            } else {
                let params: Vec<String> = info
                    .params
                    .iter()
                    .map(|p| format!("{}={}", p.name, numanos::util::fmt_f64(p.default)))
                    .collect();
                format!("{}({})", info.name, params.join(";"))
            }
        })
        .collect();
    println!("schedulers : {}", scheds.join(" "));
    // page policies carry their declared parameters, like `topo` shows
    // the fabric: `bind(node=0)` reads as "parameter node, default 0"
    let mems: Vec<String> = numanos::simnuma::page_policy_infos()
        .iter()
        .map(|info| {
            if info.params.is_empty() {
                info.name.to_string()
            } else {
                let params: Vec<String> = info
                    .params
                    .iter()
                    .map(|(name, default, _)| format!("{name}={}", numanos::util::fmt_f64(*default)))
                    .collect();
                format!("{}({})", info.name, params.join(";"))
            }
        })
        .collect();
    println!("mem        : {}", mems.join(" "));
    println!("bindings   : linear numa");
    println!("topologies : {}", Topology::preset_names().join(" "));
    println!("figures    : {}", harness::figures().iter().map(|f| f.id).collect::<Vec<_>>().join(" "));
    Ok(())
}

fn cmd_topo(flags: &HashMap<String, String>) -> Result<()> {
    let name = flags.get("name").map(String::as_str).unwrap_or("x4600");
    let topo = Topology::by_name(name)?;
    println!(
        "{}: {} nodes, {} cores, max {} hops, {} pages/node",
        topo.name(),
        topo.num_nodes(),
        topo.num_cores(),
        topo.max_hops(),
        topo.node_capacity_pages()
    );
    println!("\nnode hop matrix:");
    for a in 0..topo.num_nodes() {
        let row: Vec<String> =
            (0..topo.num_nodes()).map(|b| topo.node_hops(a, b).to_string()).collect();
        println!("  node {a:>2}: {}  (mean hops to cores: {:.2})", row.join(" "), topo.mean_hops_from(a));
    }
    let pr = core_priorities(&topo);
    println!("\nSS IV core priorities (alpha = {:?}):", pr.alpha);
    let ranked = pr.ranked_cores();
    for &c in &ranked {
        println!(
            "  core {c:>2} (node {}): P1 = {:8.2}  P = {:10.2}{}",
            topo.node_of(c),
            pr.p1[c],
            pr.scores[c],
            if c == ranked[0] { "   <- master binds here" } else { "" }
        );
    }
    Ok(())
}

fn cmd_run(flags: &HashMap<String, String>) -> Result<()> {
    if bool_flag(flags, "checked") {
        analysis::checked::set_enabled(true);
    }
    let mut builder = RunSpec::builder();
    for key in [
        "bench", "size", "sched", "policy", "mem", "bind", "cores", "threads", "topo", "seed",
        "compute", "artifacts", "cost", "rtdata",
    ] {
        if let Some(v) = flags.get(key) {
            builder.set(key, v)?;
        }
    }
    let spec = builder.build()?;
    let session = Session::new();
    let record = session.run(&spec)?;
    if bool_flag(flags, "json") {
        print!("{}", record.to_json().to_pretty());
        return Ok(());
    }
    println!("# {}", spec.describe());
    let stats = &record.stats;
    println!("{}", stats.summary());
    println!(
        "mem: l1={} l2={} miss={} (hops {:.2}) stall={} work={} overhead={}",
        stats.mem.l1_hit_lines,
        stats.mem.l2_hit_lines,
        stats.mem.miss_lines(),
        stats.mem.mean_miss_hops(),
        fmt_time(stats.mem.contention_stall),
        fmt_time(stats.work_time),
        fmt_time(stats.overhead_time),
    );
    println!(
        "serial {} -> speedup {:.2}x | efficiency {:.1}% | events {} | host {:.1} ms",
        fmt_time(record.serial_makespan),
        record.speedup,
        100.0 * stats.efficiency(),
        stats.sim_events,
        stats.wall_ms,
    );
    if stats.kernel_calls > 0 {
        println!("pjrt kernel calls: {} (verified)", stats.kernel_calls);
    }
    Ok(())
}

/// `--cost`/`--topo` figure overrides applied onto a figure's sweep.
fn figure_session_and_overrides(
    flags: &HashMap<String, String>,
) -> Result<(Session, Option<String>, Vec<(String, f64)>)> {
    let cost = flags.get("cost").map(|c| parse_cost_pairs(c)).transpose()?.unwrap_or_default();
    Ok((Session::new(), flags.get("topo").cloned(), cost))
}

fn cmd_figure(flags: &HashMap<String, String>) -> Result<()> {
    let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose().context("seed")?.unwrap_or(42);
    let size = flags
        .get("size")
        .map(|s| Size::from_name(s))
        .transpose()?
        .unwrap_or(Size::Medium);
    let (session, topo, cost) = figure_session_and_overrides(flags)?;
    let specs: Vec<harness::FigureSpec> = if bool_flag(flags, "all") {
        harness::figures()
    } else if let Some(id) = flags.get("id") {
        vec![harness::figure_by_id(id).with_context(|| format!("unknown figure '{id}'"))?]
    } else {
        bail!("figure: need --id <figN> or --all");
    };
    let out_dir = flags.get("out").cloned();
    if let Some(d) = &out_dir {
        std::fs::create_dir_all(d)?;
    }
    let json = bool_flag(flags, "json");
    let mut json_out = Vec::new();
    for mut spec in specs {
        spec.size = size;
        let mut sweep = harness::sweep_for(&spec, seed);
        if let Some(t) = &topo {
            sweep.topo = t.clone();
        }
        sweep.cost = cost.clone();
        let t0 = std::time::Instant::now();
        let result = session.run_sweep(&sweep)?;
        let table = result.table();
        if json {
            json_out.push(result.to_json());
        } else {
            let rep = harness::report(&spec, &table);
            println!("{rep}");
            println!("{}", table.to_ascii());
        }
        eprintln!("[{} took {:.1}s]", spec.id, t0.elapsed().as_secs_f64());
        if let Some(d) = &out_dir {
            std::fs::write(format!("{d}/{}.md", spec.id), harness::report(&spec, &table))?;
            std::fs::write(format!("{d}/{}.csv", spec.id), table.to_csv())?;
        }
    }
    if json {
        print!("{}", Json::Arr(json_out).to_pretty());
    }
    Ok(())
}

fn cmd_gains(flags: &HashMap<String, String>) -> Result<()> {
    let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose().context("seed")?.unwrap_or(42);
    let size = flags
        .get("size")
        .map(|s| Size::from_name(s))
        .transpose()?
        .unwrap_or(Size::Medium);
    let session = match flags.get("cost") {
        Some(spec) => {
            let mut cm = CostModel::default();
            numanos::config::parse_cost_overrides(&mut cm, spec)?;
            Session::with_cost(cm)
        }
        None => Session::new(),
    };
    let table = harness::gains_summary_with(&session, size, seed)?;
    println!("{}", table.to_markdown());
    Ok(())
}

fn cmd_sweep(flags: &HashMap<String, String>) -> Result<()> {
    if bool_flag(flags, "checked") {
        analysis::checked::set_enabled(true);
    }
    let path = flags.get("manifest").context("sweep: need --manifest <file>")?;
    let mut manifest = ExperimentManifest::load(Path::new(path))?;
    if let Some(seed) = flags.get("seed") {
        let seed: u64 = seed.parse().context("seed")?;
        for s in &mut manifest.sweeps {
            s.seeds = vec![seed];
        }
    }
    let workers = if bool_flag(flags, "seq") {
        1
    } else if let Some(w) = flags.get("workers") {
        w.parse::<usize>().context("workers")?.max(1)
    } else {
        default_workers()
    };
    let shard_plan = flags
        .get("shard")
        .map(|s| ShardPlan::parse(s))
        .transpose()
        .context("sweep: --shard")?;
    let out_dir = flags.get("out").cloned();
    if shard_plan.is_some() && (out_dir.is_some() || bool_flag(flags, "json")) {
        bail!(
            "sweep: --shard runs a partial slice, so per-sweep CSV/JSON would be partial \
             too; run `numanos merge --manifest <file> --store <dir>` after the shards \
             finish to get the full output"
        );
    }
    if let Some(d) = &out_dir {
        std::fs::create_dir_all(d)?;
    }
    let resume = bool_flag(flags, "resume");
    let no_cache = bool_flag(flags, "no-cache");
    let mut session = Session::new();
    let store = match flags.get("store") {
        Some(dir) => {
            if resume && no_cache {
                bail!("sweep: --resume re-uses cached cells, --no-cache forbids that; pick one");
            }
            if resume && !Path::new(dir).join("index.json").exists() {
                if let Some(spec) = flags.get("shard") {
                    bail!(
                        "sweep: --resume with --shard {spec} expects the shards' shared \
                         store at '{dir}' to exist already (no index.json found); start \
                         the first shard pass without --resume — any shard may create \
                         the store"
                    );
                }
                bail!(
                    "sweep: --resume expects an existing store at '{dir}' (no index.json \
                     found — nothing to resume)"
                );
            }
            let store = std::sync::Arc::new(ResultStore::open(Path::new(dir))?);
            session.set_store(store.clone(), !no_cache);
            Some(store)
        }
        None => {
            if let Some(plan) = shard_plan {
                bail!(
                    "sweep: --shard {} needs --store <dir> — the shared store is where \
                     this shard's cells land for `numanos merge` to assemble",
                    plan.spec()
                );
            }
            if resume {
                bail!("sweep: --resume needs --store <dir> (the store to resume from)");
            }
            if no_cache {
                bail!("sweep: --no-cache only makes sense with --store <dir>");
            }
            None
        }
    };
    if let Some(plan) = shard_plan {
        let store = store.as_ref().expect("checked above");
        let t0 = std::time::Instant::now();
        let before = store.counters();
        let summary = shard::run_manifest_shard(&session, store, &manifest, plan, workers)?;
        for s in &summary.sweeps {
            eprintln!(
                "[sweep '{}' shard {}: {} of {} cell(s) owned]",
                s.id,
                plan.spec(),
                s.owned,
                s.owned + s.skipped
            );
        }
        let a = store.counters();
        eprintln!(
            "[shard {}: {} of {} cell(s) in {:.1}s on {workers} worker(s), cache: {} hit / \
             {} miss / {} written; marker shards/{}.json, cells fnv {}]",
            plan.spec(),
            summary.owned_cells,
            summary.total_cells,
            t0.elapsed().as_secs_f64(),
            a.hits - before.hits,
            a.misses - before.misses,
            a.writes - before.writes,
            plan.name(),
            summary.manifest_fnv
        );
        return Ok(());
    }
    run_manifest_sweeps(
        &session,
        &manifest,
        workers,
        out_dir.as_deref(),
        bool_flag(flags, "json"),
        store.as_ref(),
        "sweep",
    )
}

/// The shared per-sweep execution + output loop behind `numanos sweep`
/// and `numanos merge`: tables (or collected JSON) to stdout, per-sweep
/// CSV/MD files under `out_dir`, cache-counter summaries to stderr.
fn run_manifest_sweeps(
    session: &Session,
    manifest: &ExperimentManifest,
    workers: usize,
    out_dir: Option<&str>,
    json: bool,
    store: Option<&std::sync::Arc<ResultStore>>,
    verb: &str,
) -> Result<()> {
    let mut json_sweeps = Vec::new();
    for sweep in &manifest.sweeps {
        let t0 = std::time::Instant::now();
        let before = store.map(|s| s.counters());
        let result = session.run_sweep_with(sweep, workers)?;
        let cache_note = match (&store, before) {
            (Some(s), Some(b)) => {
                let a = s.counters();
                format!(
                    ", cache: {} hit / {} miss / {} written",
                    a.hits - b.hits,
                    a.misses - b.misses,
                    a.writes - b.writes
                )
            }
            _ => String::new(),
        };
        eprintln!(
            "[{verb} '{}': {} cells in {:.1}s on {workers} worker(s){cache_note}]",
            sweep.id,
            result.records.len(),
            t0.elapsed().as_secs_f64()
        );
        if json {
            json_sweeps.push(result.to_json());
        } else {
            println!("{}", result.table().to_markdown());
        }
        if let Some(d) = out_dir {
            std::fs::write(format!("{d}/{}.csv", sweep.id), result.to_csv())?;
            std::fs::write(format!("{d}/{}.md", sweep.id), result.table().to_markdown())?;
        }
    }
    if let Some(s) = store {
        let c = s.counters();
        if c.quarantined > 0 {
            eprintln!(
                "[{verb}: {} corrupt store record(s) quarantined under '{}/quarantine' and \
                 re-executed]",
                c.quarantined,
                s.root().display()
            );
        }
    }
    if json {
        let doc = Json::obj([
            ("title", Json::from(manifest.title.as_str())),
            ("sweeps", Json::Arr(json_sweeps)),
        ]);
        print!("{}", doc.to_pretty());
    }
    Ok(())
}

/// `numanos merge`: re-run a full manifest against the shards' shared
/// store — 100% cache hits when every shard finished — and emit the
/// CSV/JSON a sequential single-process sweep would have produced, byte
/// for byte.  Reports the shard-marker census first; `--merge-strict`
/// turns missing/stale markers or any cache miss into a hard failure.
fn cmd_merge(flags: &HashMap<String, String>) -> Result<()> {
    if bool_flag(flags, "checked") {
        analysis::checked::set_enabled(true);
    }
    let path = flags.get("manifest").context("merge: need --manifest <file>")?;
    let mut manifest = ExperimentManifest::load(Path::new(path))?;
    if let Some(seed) = flags.get("seed") {
        let seed: u64 = seed.parse().context("seed")?;
        for s in &mut manifest.sweeps {
            s.seeds = vec![seed];
        }
    }
    let workers = if bool_flag(flags, "seq") {
        1
    } else if let Some(w) = flags.get("workers") {
        w.parse::<usize>().context("workers")?.max(1)
    } else {
        default_workers()
    };
    let dir = flags
        .get("store")
        .context("merge: need --store <dir> (the shards' shared store)")?;
    if !Path::new(dir).join("index.json").exists() {
        bail!(
            "merge: no store at '{dir}' (no index.json found); run the shards first \
             (`numanos sweep --manifest {path} --shard I/N --store {dir}`)"
        );
    }
    let store = std::sync::Arc::new(ResultStore::open(Path::new(dir))?);
    let strict = bool_flag(flags, "merge-strict");
    let fnv = shard::manifest_fingerprint(&manifest)?;
    let status = shard::shard_status(&store, &fnv);
    let stale_note = if status.stale.is_empty() {
        String::new()
    } else {
        format!(", stale marker(s): {}", status.stale.join(", "))
    };
    match status.count {
        Some(n) => {
            let missing_note = if status.missing.is_empty() {
                String::new()
            } else {
                let list: Vec<String> =
                    status.missing.iter().map(|i| i.to_string()).collect();
                format!(", missing shard(s): {}", list.join(", "))
            };
            eprintln!(
                "[merge: {} of {n} shard marker(s) present for cells fnv \
                 {fnv}{missing_note}{stale_note}]",
                status.present.len()
            );
        }
        None => eprintln!("[merge: no shard markers match cells fnv {fnv}{stale_note}]"),
    }
    if strict {
        if status.count.is_none() {
            bail!(
                "merge --merge-strict: no shard markers for this manifest under \
                 '{dir}/shards'{stale_note}"
            );
        }
        if !status.missing.is_empty() {
            let list: Vec<String> = status.missing.iter().map(|i| i.to_string()).collect();
            bail!(
                "merge --merge-strict: shard(s) {} of {} have not completed",
                list.join(", "),
                status.count.unwrap_or(0)
            );
        }
        if !status.stale.is_empty() {
            bail!(
                "merge --merge-strict: stale shard marker(s) {} — the store was sharded \
                 for a different manifest",
                status.stale.join(", ")
            );
        }
    }
    let mut session = Session::new();
    session.set_store(store.clone(), true);
    let out_dir = flags.get("out").cloned();
    if let Some(d) = &out_dir {
        std::fs::create_dir_all(d)?;
    }
    let before = store.counters();
    run_manifest_sweeps(
        &session,
        &manifest,
        workers,
        out_dir.as_deref(),
        bool_flag(flags, "json"),
        Some(&store),
        "merge",
    )?;
    let after = store.counters();
    if strict && after.misses > before.misses {
        bail!(
            "merge --merge-strict: {} cell(s) missed the store and re-executed (shards \
             incomplete, stale, or quarantined records)",
            after.misses - before.misses
        );
    }
    Ok(())
}

/// `numanos serve`: the filesystem-spool manifest service (see
/// [`numanos::store::serve`]).
fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    let store = flags.get("store").context("serve: need --store <dir> (the shared store)")?;
    let spool = flags
        .get("spool")
        .context("serve: need --spool <dir> (where clients drop manifests)")?;
    let poll_ms: u64 =
        flags.get("poll-ms").map(|s| s.parse()).transpose().context("poll-ms")?.unwrap_or(500);
    let workers = match flags.get("workers") {
        Some(w) => w.parse::<usize>().context("workers")?.max(1),
        None => default_workers(),
    };
    let opts = serve::ServeOptions { poll_ms, once: bool_flag(flags, "once"), workers };
    serve::serve(Path::new(store), Path::new(spool), &opts)
}

/// `numanos bench`: run the pinned suite (default), or `--compare` two
/// emitted reports.
fn cmd_bench(flags: &HashMap<String, String>, positionals: &[String]) -> Result<()> {
    if bool_flag(flags, "compare") {
        return cmd_bench_compare(flags, positionals);
    }
    if bool_flag(flags, "checked") {
        analysis::checked::set_enabled(true);
    }
    if !positionals.is_empty() {
        bail!(
            "bench: positional arguments are only used with --compare <old.json> <new.json> \
             (got '{}')",
            positionals.join(" ")
        );
    }
    let reps: usize =
        flags.get("reps").map(|s| s.parse()).transpose().context("reps")?.unwrap_or(3);
    if reps == 0 {
        bail!("bench: --reps must be at least 1");
    }
    let filter = flags.get("filter").map(String::as_str).unwrap_or("");
    let out = flags.get("out").map(String::as_str).unwrap_or("BENCH.json");
    let entries = bench::filtered(filter)?;
    let session = Session::new();
    let t0 = std::time::Instant::now();
    let mut cells = Vec::new();
    for entry in &entries {
        let t1 = std::time::Instant::now();
        let entry_cells = bench::run_entry(&session, entry, reps)?;
        eprintln!(
            "[bench {}: {} cell(s) x {reps} rep(s) in {:.1}s]",
            entry.sweep.id,
            entry_cells.len(),
            t1.elapsed().as_secs_f64()
        );
        cells.extend(entry_cells);
    }
    let total_wall_ms: f64 = cells.iter().map(|c| c.wall_ms).sum();
    let run =
        bench::SuiteRun { reps, filter: filter.to_string(), cells, total_wall_ms };
    let doc = run.to_json();
    std::fs::write(out, doc.to_pretty()).with_context(|| format!("writing {out}"))?;
    eprintln!(
        "[bench: {} cell(s) -> {out} in {:.1}s]",
        run.cells.len(),
        t0.elapsed().as_secs_f64()
    );
    if bool_flag(flags, "json") {
        print!("{}", doc.to_pretty());
    } else {
        println!(
            "wrote {out}: {} cell(s), suite wall {:.1} ms (per-cell median of {reps} rep(s))",
            run.cells.len(),
            run.total_wall_ms
        );
    }
    Ok(())
}

/// `numanos bench --compare old.json new.json`: render the delta report
/// and exit non-zero on threshold breach.
fn cmd_bench_compare(flags: &HashMap<String, String>, positionals: &[String]) -> Result<()> {
    let [old_path, new_path] = positionals else {
        bail!("bench --compare needs exactly two files: <old.json> <new.json>");
    };
    let old = bench::SuiteReport::load(Path::new(old_path))?;
    let new = bench::SuiteReport::load(Path::new(new_path))?;
    if !old.cells.is_empty() && old.cells.iter().all(|c| c.sim.is_none()) {
        eprintln!(
            "[bench compare: baseline {old_path} is all-placeholder (every cell has sim: null) \
             — every delta below is Unmeasured; run `numanos bench` on the baseline commit and \
             commit the emitted report to start the perf trajectory]"
        );
    }
    let defaults = bench::compare::CompareOptions::default();
    let opts = bench::compare::CompareOptions {
        max_regress_pct: flags
            .get("max-regress-pct")
            .map(|s| s.parse())
            .transpose()
            .context("max-regress-pct")?
            .unwrap_or(defaults.max_regress_pct),
        wall_warn_pct: flags
            .get("wall-warn-pct")
            .map(|s| s.parse())
            .transpose()
            .context("wall-warn-pct")?
            .unwrap_or(defaults.wall_warn_pct),
        fail_on_drift: bool_flag(flags, "fail-on-drift"),
        warn_only: bool_flag(flags, "warn-only"),
    };
    let cmp = bench::compare::compare(&old, &new, &opts)?;
    if bool_flag(flags, "json") {
        print!("{}", cmp.to_json().to_pretty());
    } else {
        print!("{}", cmp.render());
    }
    if cmp.failed(&opts) {
        bail!(
            "bench compare failed: {} regression(s) past {}%, {} drifted cell(s){}",
            cmp.regressions,
            opts.max_regress_pct,
            cmp.drifted,
            if opts.fail_on_drift { " (--fail-on-drift)" } else { "" }
        );
    }
    Ok(())
}

/// `numanos vet [scheduler] | --all`: the scheduler contract checker
/// ([`analysis::vet`]).  Exits non-zero on any error-severity finding.
fn cmd_vet(flags: &HashMap<String, String>, positionals: &[String]) -> Result<()> {
    let all = bool_flag(flags, "all");
    let (diags, vetted) = match (all, positionals.first()) {
        (true, Some(_)) => bail!("vet: give a scheduler name or --all, not both"),
        (true, None) => (analysis::vet::vet_all()?, sched::scheduler_names().len()),
        (false, Some(name)) => (analysis::vet::vet_scheduler(name)?, 1),
        (false, None) => bail!("vet: need a scheduler name or --all (try `numanos list`)"),
    };
    if bool_flag(flags, "json") {
        print!("{}", analysis::diagnostics_to_json(&diags).to_pretty());
    } else if diags.is_empty() {
        println!("vet: {vetted} scheduler(s) clean");
    } else {
        print!("{}", analysis::render_table(&diags));
    }
    let errors = analysis::error_count(&diags);
    if errors > 0 {
        bail!("vet: {errors} contract violation(s) ({} finding(s) total)", diags.len());
    }
    Ok(())
}

/// `numanos lint --manifest <file> | --dir <dir>`: the static input
/// linter ([`analysis::lint`]).  Exits non-zero on any error finding.
fn cmd_lint(flags: &HashMap<String, String>) -> Result<()> {
    let diags = match (flags.get("manifest"), flags.get("dir")) {
        (Some(_), Some(_)) => bail!("lint: give --manifest or --dir, not both"),
        (Some(file), None) => {
            let path = Path::new(file);
            if path.extension().and_then(|e| e.to_str()) == Some("conf") {
                analysis::lint::lint_config(path)
            } else {
                analysis::lint::lint_manifest(path)
            }
        }
        (None, Some(dir)) => analysis::lint::lint_dir(Path::new(dir))?,
        (None, None) => bail!("lint: need --manifest <file> or --dir <dir>"),
    };
    if bool_flag(flags, "json") {
        print!("{}", analysis::diagnostics_to_json(&diags).to_pretty());
    } else if diags.is_empty() {
        println!("lint: clean");
    } else {
        print!("{}", analysis::render_table(&diags));
    }
    let errors = analysis::error_count(&diags);
    if errors > 0 {
        bail!("lint: {errors} error(s) ({} finding(s) total)", diags.len());
    }
    Ok(())
}
