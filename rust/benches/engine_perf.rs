//! L3 engine performance: simulated-events-per-second throughput.
//!
//! This is the simulator's own hot path (EXPERIMENTS.md §Perf): the
//! figure harness runs ~400 simulations, so engine throughput bounds the
//! whole reproduction loop.  Targets (DESIGN.md §8): ≥ 1M events/s on the
//! task-heavy workloads, full fig sweep << 2 min.
//!
//! The workload table is `numanos::bench::perf_entries()` — the same six
//! Medium-size cells `numanos bench` records under the `perf` group — so
//! a throughput number printed here lines up one-to-one with a `wall_ms`
//! entry in `BENCH_*.json` and the `--compare` trajectory over commits.

use numanos::bench;
use numanos::spec::Session;

fn main() -> anyhow::Result<()> {
    let session = Session::new();
    println!(
        "{:<22} {:>9} {:>10} {:>11} {:>12} {:>10}",
        "cell", "tasks", "events", "wall-ms", "events/s", "tasks/s"
    );
    let mut worst_eps = f64::INFINITY;
    for entry in bench::perf_entries() {
        // median-of-3 wall clock (host noise), same aggregation as the
        // bench suite's --reps
        let cells = bench::run_entry(&session, &entry, 3)?;
        for cell in cells {
            let stats = &cell.record.stats;
            let wall_s = cell.wall_ms / 1e3;
            let eps = stats.sim_events as f64 / wall_s;
            worst_eps = worst_eps.min(eps);
            println!(
                "{:<22} {:>9} {:>10} {:>11.1} {:>12.0} {:>10.0}",
                format!("{}/{}", stats.bench, stats.sched),
                stats.tasks,
                stats.sim_events,
                cell.wall_ms,
                eps,
                stats.tasks as f64 / wall_s,
            );
        }
    }
    println!("\nworst-case engine throughput: {:.2}M events/s", worst_eps / 1e6);
    Ok(())
}
