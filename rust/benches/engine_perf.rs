//! L3 engine performance: simulated-events-per-second throughput.
//!
//! This is the simulator's own hot path (EXPERIMENTS.md §Perf): the
//! figure harness runs ~400 simulations, so engine throughput bounds the
//! whole reproduction loop.  Targets (DESIGN.md §8): ≥ 1M events/s on the
//! task-heavy workloads, full fig sweep << 2 min.

use std::time::Instant;

use numanos::bots;
use numanos::config::Size;
use numanos::coordinator::binding::BindPolicy;
use numanos::coordinator::runtime::Runtime;
use numanos::coordinator::sched::Policy;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::paper_testbed();
    println!(
        "{:<18} {:>9} {:>10} {:>11} {:>12} {:>10}",
        "workload", "tasks", "events", "wall-ms", "events/s", "tasks/s"
    );
    let mut worst_eps = f64::INFINITY;
    for (bench, size, policy) in [
        ("fft", Size::Medium, Policy::WorkFirst),
        ("fft", Size::Medium, Policy::BreadthFirst),
        ("sort", Size::Medium, Policy::Dfwsrpt),
        ("uts", Size::Medium, Policy::Dfwsrpt),
        ("sparselu_for", Size::Medium, Policy::Dfwspt),
        ("nqueens", Size::Medium, Policy::BreadthFirst),
    ] {
        // best-of-3 wall clock (host noise)
        let mut best: Option<(f64, u64, u64)> = None;
        for rep in 0..3 {
            let mut w = bots::create(bench, size, 42 + rep)?;
            let t0 = Instant::now();
            let s = rt.run(w.as_mut(), policy, BindPolicy::NumaAware, 16, 42, None)?;
            let wall = t0.elapsed().as_secs_f64();
            if best.map_or(true, |(b, _, _)| wall < b) {
                best = Some((wall, s.sim_events, s.tasks));
            }
        }
        let (wall, events, tasks) = best.unwrap();
        let eps = events as f64 / wall;
        worst_eps = worst_eps.min(eps);
        println!(
            "{:<18} {:>9} {:>10} {:>11.1} {:>12.0} {:>10.0}",
            format!("{bench}/{}", policy.name()),
            tasks,
            events,
            wall * 1e3,
            eps,
            tasks as f64 / wall,
        );
    }
    println!("\nworst-case engine throughput: {:.2}M events/s", worst_eps / 1e6);
    Ok(())
}
