//! E13 — ablation of the work-stealing design choices (§VI).
//!
//! Sweeps the two axes the DFWSPT/DFWSRPT design fixes:
//!
//! * **victim selection** — uniform random (wf) vs hop-ordered priority
//!   list (dfwspt) vs randomized-within-distance-group (dfwsrpt);
//! * **steal end** — oldest task (wf/dfwspt/dfwsrpt, steal-back) vs most
//!   recent parent (cilk, steal-front).
//!
//! Reports speedup, steal volume and mean steal distance on the steal-
//! heavy Strassen plus the single-generator SparseLU (every task stolen).

use numanos::bots;
use numanos::config::Size;
use numanos::coordinator::binding::BindPolicy;
use numanos::coordinator::runtime::Runtime;
use numanos::coordinator::sched::Policy;
use numanos::metrics::speedup;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::paper_testbed();
    let seed = 42;
    for bench in ["strassen", "sparselu_single"] {
        let mut serial_w = bots::create(bench, Size::Medium, seed)?;
        let serial = rt.run_serial(serial_w.as_mut(), seed)?;
        println!("\n== {bench} (16 threads, NUMA binding) ==");
        println!(
            "  {:<8} {:>8} {:>8} {:>11} {:>10}",
            "policy", "speedup", "steals", "steal-hops", "lockwait-us"
        );
        let mut by_policy = Vec::new();
        for &policy in &[Policy::CilkBased, Policy::WorkFirst, Policy::Dfwspt, Policy::Dfwsrpt] {
            let mut w = bots::create(bench, Size::Medium, seed)?;
            let s = rt.run(w.as_mut(), policy, BindPolicy::NumaAware, 16, seed, None)?;
            println!(
                "  {:<8} {:>7.2}x {:>8} {:>11.2} {:>10}",
                policy.name(),
                speedup(&serial, &s),
                s.steals,
                s.mean_steal_hops,
                s.lock_wait_total / 1_000_000,
            );
            by_policy.push((policy, s));
        }
        // the design claim: priority-list stealing shortens steal paths.
        // (sparselu_single is the degenerate case: every task starts in the
        // master's pool, so steal distance is victim-order independent —
        // allow equality within noise there.)
        let wf_hops = by_policy.iter().find(|(p, _)| *p == Policy::WorkFirst).unwrap().1.mean_steal_hops;
        let pt_hops = by_policy.iter().find(|(p, _)| *p == Policy::Dfwspt).unwrap().1.mean_steal_hops;
        assert!(
            pt_hops <= wf_hops + 0.05,
            "{bench}: dfwspt steal distance {pt_hops:.2} must not exceed wf {wf_hops:.2}"
        );
    }
    println!("\nablation_steal done (priority-list stealing shortens steal paths)");
    Ok(())
}
