//! Regenerates paper FIG8: Strassen speedup on the simulated X4600.
//!
//! Sweeps the figure's scheduler configurations over the paper's thread
//! axis against a fresh serial baseline and prints measured-vs-published
//! anchors.  `NUMANOS_SIZE=small|medium|large` and `NUMANOS_SEED`
//! override the defaults; output also lands in `results/fig8.{md,csv}`.

fn main() -> anyhow::Result<()> {
    numanos::harness::bench_figure_main("fig8")
}
