//! E11 — topology & memory-model validation (paper §II / Fig 1).
//!
//! Checks the simulated X4600 against the published properties (8 nodes x
//! 2 cores, hop distances 0-3, corner sockets less central) and measures
//! the effective NUMA factors the cost model produces: the per-hop access
//! latency ratios a `numactl`-style microbenchmark would report.

use numanos::simnuma::{CostModel, MemSim, PAGE_BYTES};
use numanos::topology::Topology;
use numanos::util::Time;

fn stream_cost(hops_target: u8) -> (Time, u8) {
    // place data via core 0 (node 0), stream it from a core `hops` away
    let topo = Topology::x4600();
    // exclude core 0 itself: it first-touched the data, so its caches are
    // warm — the microbenchmark wants the cold-DRAM NUMA factor
    let reader = (1..topo.num_cores())
        .find(|&c| topo.core_hops(0, c) == hops_target)
        .expect("no core at that distance");
    let mut mem = MemSim::new(topo, CostModel::default());
    let region = mem.alloc(64 * PAGE_BYTES);
    mem.first_touch(0, region, 0);
    (mem.access(reader, region, false, 0), hops_target)
}

fn main() {
    let topo = Topology::x4600();
    println!("== X4600 model validation ==");
    println!(
        "nodes={} cores={} max_hops={}",
        topo.num_nodes(),
        topo.num_cores(),
        topo.max_hops()
    );
    assert_eq!((topo.num_nodes(), topo.num_cores(), topo.max_hops()), (8, 16, 3));

    println!("\nnode centrality (mean hops to all cores):");
    for node in 0..8 {
        println!("  node {node}: {:.2}", topo.mean_hops_from(node));
    }
    let corner = [0usize, 1, 6, 7];
    let inner = [2usize, 3, 4, 5];
    let worst_inner = inner.iter().map(|&n| topo.mean_hops_from(n)).fold(0.0, f64::max);
    let best_corner =
        corner.iter().map(|&n| topo.mean_hops_from(n)).fold(f64::INFINITY, f64::min);
    assert!(worst_inner < best_corner, "corner sockets must be less central");

    println!("\nmeasured streaming NUMA factors (cold 256 KiB read):");
    let (local, _) = stream_cost(0);
    for hops in 0..=3u8 {
        let (cost, _) = stream_cost(hops);
        println!(
            "  {hops} hop(s): {:>9} ns  factor {:.2}",
            cost / 1000,
            cost as f64 / local as f64
        );
        if hops > 0 {
            assert!(cost > local, "remote must cost more than local");
        }
    }
    let (far, _) = stream_cost(3);
    let factor = far as f64 / local as f64;
    assert!(
        (1.3..4.5).contains(&factor),
        "3-hop factor {factor:.2} outside the Opteron-plausible band"
    );
    println!("\ntopo_validation OK (factors within the X4600-plausible band)");
}
