//! E12 — ablation of the §IV priority formula on the data-heavy FFT.
//!
//! Compares four placement score functions fed to the same binding logic:
//!
//! * `flat`     — all cores equal (master lands on core 0: the baseline);
//! * `base`     — node-size term only (first attribution level);
//! * `v1`       — base + Fig-2 weighted neighbour counts;
//! * `v1+v2`    — the full Fig-3/Fig-4 two-pass priority (the paper's).
//!
//! On the homogeneous X4600 `base` is flat (all nodes have 2 cores), so
//! the interesting deltas are flat → v1 → v1+v2; the heterogeneous
//! variant shows where the base term earns its keep.

use numanos::bots;
use numanos::config::Size;
use numanos::coordinator::binding::bind_with_scores;
use numanos::coordinator::priority::{alpha_weights, core_priorities, weighted_hop_matrix};
use numanos::coordinator::runtime::Runtime;
use numanos::coordinator::sched::Policy;
use numanos::metrics::speedup;
use numanos::topology::Topology;
use numanos::util::SplitMix64;

fn scores(topo: &Topology, mode: &str) -> Vec<f64> {
    let n = topo.num_cores();
    let alpha = alpha_weights(topo.max_hops());
    let a = weighted_hop_matrix(topo, &alpha);
    match mode {
        "flat" => vec![0.0; n],
        "base" => (0..n).map(|c| topo.cores_per_node(topo.node_of(c)) as f64).collect(),
        "v1" => (0..n)
            .map(|c| {
                topo.cores_per_node(topo.node_of(c)) as f64 + a[c].iter().sum::<f64>()
            })
            .collect(),
        "v1+v2" => core_priorities(topo).scores,
        _ => unreachable!(),
    }
}

fn main() -> anyhow::Result<()> {
    let seed = 42;
    for topo_name in ["x4600", "x4600_hetero"] {
        let topo = Topology::by_name(topo_name)?;
        let rt = Runtime::new(topo.clone(), Default::default());
        let mut serial_w = bots::create("fft", Size::Medium, seed)?;
        let serial = rt.run_serial(serial_w.as_mut(), seed)?;
        println!("\n== {topo_name} (fft medium, wf, 16 threads) ==");
        for mode in ["flat", "base", "v1", "v1+v2"] {
            let sc = scores(&topo, mode);
            let mut rng = SplitMix64::new(seed);
            let cores = bind_with_scores(&topo, 16, &sc, &mut rng);
            let mut w = bots::create("fft", Size::Medium, seed)?;
            let stats =
                rt.run_bound(w.as_mut(), Policy::WorkFirst, &cores, true, seed, None)?;
            println!(
                "  {mode:<6} master core {:>2} (node {}) | speedup {:.2}x | miss hops {:.2}",
                cores[0],
                topo.node_of(cores[0]),
                speedup(&serial, &stats),
                stats.mem.mean_miss_hops(),
            );
        }
    }
    println!("\nablation_priority done");
    Ok(())
}
