//! Offline drop-in subset of the `anyhow` crate.
//!
//! The build image carries no crates.io registry, so this shim provides
//! the slice of anyhow the codebase uses, source-compatible with the real
//! crate so swapping back is a one-line Cargo change:
//!
//! * [`Error`] — a context-chain error: `Display` prints the outermost
//!   message, `{:#}` prints the whole chain (`outer: inner: root`);
//! * [`Result<T>`] with the `Error` default;
//! * [`anyhow!`] / [`bail!`] / [`ensure!`] macros;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on both
//!   `Result` (any error convertible into [`Error`], including `Error`
//!   itself) and `Option`;
//! * `From<E: std::error::Error + Send + Sync + 'static>` so `?` works on
//!   std errors, flattening their `source()` chain into context layers.
//!
//! Deliberately NOT implemented (unused here): backtraces, downcasting,
//! `Error::new` over non-`Display` payloads.

use std::fmt;

/// `Result` with a defaulted anyhow error, exactly like upstream.
pub type Result<T, E = Error> = core::result::Result<T, E>;

/// A message plus an optional chain of underlying causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message (the `{:#}` chain
    /// reads outermost-first, matching upstream anyhow).
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// Iterate the chain outermost-first (the `Display` message of each
    /// layer).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut layers = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            layers.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        layers.into_iter()
    }

    /// The innermost error message.
    pub fn root_cause(&self) -> &str {
        let mut cur = self;
        while let Some(src) = cur.source.as_deref() {
            cur = src;
        }
        &cur.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if self.source.is_some() {
            f.write_str("\n\nCaused by:")?;
            let mut cur = self.source.as_deref();
            let mut i = 0;
            while let Some(e) = cur {
                write!(f, "\n    {i}: {}", e.msg)?;
                cur = e.source.as_deref();
                i += 1;
            }
        }
        Ok(())
    }
}

// `Error` intentionally does NOT implement `std::error::Error`: that is
// what keeps this blanket `From` coherent next to core's identity
// `impl From<T> for T` (the same trick upstream anyhow relies on).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut layers = vec![e.to_string()];
        let mut cur: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(s) = cur {
            layers.push(s.to_string());
            cur = s.source();
        }
        let mut it = layers.into_iter().rev();
        let mut err = Error::msg(it.next().expect("at least one layer"));
        for outer in it {
            err = err.context(outer);
        }
        err
    }
}

/// Context-attachment on fallible values, as in upstream anyhow.
pub trait Context<T>: Sized {
    /// Attach a context message, converting the error into [`Error`].
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Attach a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for core::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`Error`] built as by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Bail unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !$cond {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_num(s: &str) -> Result<i64> {
        let n: i64 = s.parse().context("parsing a number")?;
        Ok(n)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse_num("42").unwrap(), 42);
        let e = parse_num("x").unwrap_err();
        assert_eq!(e.msg, "parsing a number");
        assert!(format!("{e:#}").starts_with("parsing a number: "));
    }

    #[test]
    fn context_chains_display() {
        let e = Error::msg("root").context("mid").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: mid: root");
        assert_eq!(e.root_cause(), "root");
        assert_eq!(e.chain().count(), 3);
    }

    #[test]
    fn context_on_option_and_anyhow_result() {
        let none: Option<u8> = None;
        assert_eq!(format!("{}", none.context("missing").unwrap_err()), "missing");
        let r: Result<u8> = Err(anyhow!("inner {}", 7));
        let e = r.with_context(|| "outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner 7");
    }

    #[test]
    fn macros_build_and_bail() {
        fn f(flag: bool) -> Result<u8> {
            ensure!(!flag, "flag was {flag}");
            if flag {
                bail!("unreachable");
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(format!("{}", f(true).unwrap_err()), "flag was true");
        let e = anyhow!("n = {}", 3);
        assert_eq!(format!("{e}"), "n = 3");
    }

    #[test]
    fn debug_lists_causes() {
        let e = Error::msg("root").context("outer");
        let d = format!("{e:?}");
        assert!(d.contains("outer") && d.contains("Caused by") && d.contains("root"));
    }
}
